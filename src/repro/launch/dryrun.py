import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the 512
placeholder host devices let jax.make_mesh build the production meshes; all
inputs are ShapeDtypeStruct stand-ins (no allocation); ``.compile()``
succeeding means sharding propagation, collectives, and memory planning all
close. Results (memory_analysis, cost_analysis, collective schedule,
roofline terms) stream into a JSON file consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh both --out experiments/dryrun.json
"""
import argparse
import functools
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, get_arch, input_specs, list_archs
from repro.distributed.sharding import (cache_shardings, param_shardings,
                                        use_mesh, _dp_axes)
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch.roofline import (Roofline, collective_bytes, extract_cost,
                                   extract_memory)
from repro.models import model as M
from repro.train.optimizer import adamw_init, zero1_shardings
from repro.train.step import make_prefill_step, make_serve_step, make_train_step


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(M.init_params, cfg),
                          jax.random.PRNGKey(0))


def _dp_total(mesh):
    return mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)


def batch_shardings(mesh, specs: dict, *, long_context: bool):
    dp = _dp_axes(mesh)
    total = _dp_total(mesh)

    def spec_of(name, leaf):
        if name == "pos":
            return NamedSharding(mesh, P())
        if leaf.shape and leaf.shape[0] % total == 0:
            return NamedSharding(mesh, P(*((dp,) + (None,) * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*((None,) * leaf.ndim)))

    return {k: (cache_shardings(v, mesh, shard_seq=long_context)
                if k == "cache" else
                jax.tree.map(lambda leaf, kk=k: spec_of(kk, leaf), v))
            for k, v in specs.items()}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             bf16_grads: bool = True, sharding_mode: str = "tp",
             moe_impl: str | None = None, kv_dtype: str | None = None) -> dict:
    import dataclasses
    cfg = get_arch(arch)
    if moe_impl and cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "sharding": sharding_mode, "bf16_grads": bf16_grads,
                 "moe_impl": moe_impl or (cfg.moe_impl if cfg.n_experts else None),
                 "kv_dtype": kv_dtype or "bf16"}
    if not cfg.supports(shape_name):
        rec["status"] = "skipped"
        rec["reason"] = cfg.skip_reason(shape_name)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    params_abs = abstract_params(cfg)
    n_params = int(sum(x.size for x in jax.tree.leaves(params_abs)))
    n_active = n_params
    if cfg.n_experts:
        expert = sum(x.size for p, x in
                     jax.tree_util.tree_leaves_with_path(params_abs)
                     if "w_gate" in str(p) or "w_down" in str(p))
        n_active = int(n_params - expert * (1 - cfg.moe_top_k / cfg.n_experts))
    rec["n_params"] = n_params
    rec["n_active_params"] = n_active

    specs = input_specs(cfg, shape, kv_dtype=kv_dtype)
    long_context = shape_name == "long_500k"

    with use_mesh(mesh), mesh:
        p_sh = param_shardings(params_abs, mesh, mode=sharding_mode)
        b_sh = batch_shardings(mesh, specs, long_context=long_context)

        if shape.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            zs = zero1_shardings(p_sh, params_abs, mesh)
            o_sh = type(opt_abs)(step=NamedSharding(mesh, P()), m=zs, v=zs)
            step = make_train_step(cfg, bf16_grads=bf16_grads)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs)
            # tokens processed per step (for MODEL_FLOPS = 6·N·D)
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_abs, specs)
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * n_active * tokens
        else:  # decode
            step = make_serve_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, b_sh["cache"],
                                           b_sh["tokens"], b_sh["pos"]),
                             out_shardings=(None, b_sh["cache"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, specs["cache"],
                                   specs["tokens"], specs["pos"])
            tokens = shape.global_batch  # one token per sequence
            model_flops = 2.0 * n_active * tokens

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = extract_memory(compiled)
        cost = extract_cost(compiled)
        hlo = compiled.as_text()
        colls = collective_bytes(hlo)
        rl = Roofline(flops=cost["flops"], hbm_bytes=cost["bytes"],
                      coll_bytes=colls["total_bytes"],
                      model_flops=model_flops / chips, chips=chips)
        rec.update(status="ok", chips=chips, memory=mem,
                   cost={"flops": cost["flops"], "bytes": cost["bytes"]},
                   collectives=colls, roofline=rl.as_dict(),
                   hlo_bytes=len(hlo))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--no-bf16-grads", action="store_true")
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--moe-impl", default=None, choices=[None, "dense", "sorted"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    if args.seq_parallel:
        from repro.distributed.sharding import set_sequence_parallel
        set_sequence_parallel(True)

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = (arch, shape, "2x16x16" if multi else "16x16")
                if key in done:
                    print(f"[skip-done] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, multi,
                                   bf16_grads=not args.no_bf16_grads,
                                   sharding_mode=args.sharding,
                                   moe_impl=args.moe_impl,
                                   kv_dtype="int8" if args.kv_int8 else None)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": key[2],
                           "status": "error", "error": str(e)[:2000],
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec.get("status")
                if status == "ok":
                    rl = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"dominant={rl['dominant']} "
                          f"frac={rl['roofline_fraction']:.3f} "
                          f"mem={rec['memory'].get('total_device_bytes', 0)/2**30:.2f}GiB",
                          flush=True)
                else:
                    print(f"  {status}: {rec.get('reason', rec.get('error', ''))[:200]}",
                          flush=True)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
