"""Production mesh builders (single-pod 16×16, multi-pod 2×16×16).

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
