"""Dry-run of the online scheduler: micro-batching efficiency vs the
flush-deadline knob, with no data plane (hypothetical plans, null executor).

For each ``max_delay_ms`` setting, replays the same synthetic arrival
stream through a ``MicroBatcher`` and counts the kernel dispatches the
flushed plan groups WOULD cost (``serve.compiler.dispatch_plan``) — the
scheduling analogue of ``launch/search_dryrun.py``'s collective schedule:
how much batch formation amortizes dispatch overhead before any kernel
runs, and what queueing delay buys that amortization.

    PYTHONPATH=src python -m repro.launch.online_dryrun [--queries 512]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core.types import IndexSpec, Query, QueryPlan
from repro.online.scheduler import MicroBatcher
from repro.serve.compiler import compile_batch, dispatch_plan


def synthetic_stream(n_queries: int, qps: float, seed: int = 0):
    """Timed (query, plan) arrivals over a 3-column schema — same
    hypothetical-plan construction as search_dryrun.plan_group_stats."""
    rng = np.random.default_rng(seed)
    specs = [IndexSpec(vid=(c,), kind="ivf") for c in range(3)]
    t = 0.0
    out = []
    for qid in range(n_queries):
        t += float(rng.exponential(1.0 / qps))
        vid = tuple(sorted(rng.choice(3, size=int(rng.integers(1, 4)),
                                      replace=False).tolist()))
        q = Query(qid=qid, vid=vid,
                  vectors={c: np.zeros(8, np.float32) for c in vid}, k=50)
        used = [s for s in specs if s.vid[0] in vid]
        eks = [int(rng.choice([50, 100, 150]))] * len(used)
        out.append((t, q, QueryPlan(qid, used, eks, 0.0, 1.0)))
    return out


def run_schedule(stream, max_batch: int, max_delay_ms: float) -> dict:
    totals = {"batched_scan_dispatches": 0, "per_query_scan_dispatches": 0}
    batches = []

    def execute(tickets):
        stats = dispatch_plan(compile_batch([(t.query, t.plan)
                                             for t in tickets]))
        totals["batched_scan_dispatches"] += stats["batched_scan_dispatches"]
        totals["per_query_scan_dispatches"] += stats["per_query_scan_dispatches"]
        batches.append(len(tickets))
        return [None] * len(tickets)

    plans = {q.qid: plan for _, q, plan in stream}
    mb = MicroBatcher(execute, plan_for=lambda q: plans[q.qid],
                      max_batch=max_batch, max_delay_ms=max_delay_ms)
    tickets = []
    for t, q, _ in stream:
        tickets.append(mb.submit(q, now=t))
        mb.poll(now=t)
    mb.drain(now=stream[-1][0])
    waits = [tk.wait_ms for tk in tickets]
    return {
        "max_delay_ms": max_delay_ms,
        "max_batch": max_batch,
        "batches": len(batches),
        "mean_batch": float(np.mean(batches)),
        "mean_wait_ms": float(np.mean(waits)),
        "p99_wait_ms": float(np.percentile(waits, 99)),
        "batched_scan_dispatches": totals["batched_scan_dispatches"],
        "per_query_scan_dispatches": totals["per_query_scan_dispatches"],
        "dispatch_reduction": (totals["per_query_scan_dispatches"]
                               / max(totals["batched_scan_dispatches"], 1)),
        "flush_reasons": mb.stats.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--qps", type=float, default=2000.0)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--out", default="experiments/online_dryrun.json")
    args = ap.parse_args()

    stream = synthetic_stream(args.queries, args.qps)
    out = []
    for delay in (0.5, 2.0, 5.0, 10.0, 25.0):
        rec = run_schedule(stream, args.max_batch, delay)
        out.append(rec)
        print(f"delay={delay:5.1f}ms: {rec['batches']:4d} batches "
              f"(mean {rec['mean_batch']:5.1f}), dispatch reduction "
              f"{rec['dispatch_reduction']:5.2f}x, p99 wait "
              f"{rec['p99_wait_ms']:5.1f}ms")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
