"""Roofline post-processing + EXPERIMENTS.md table generation.

Why analytic terms: XLA's cost_analysis counts while-loop (lax.scan) bodies
ONCE — for layer-scanned models every per-step quantity is undercounted by
~n_layers (and nested attention-chunk scans compound it). The dry-run JSON
keeps the raw measured values; this module adds closed-form per-(arch ×
shape × mesh) accounting with documented coefficients, used for the §Roofline
tables and the §Perf iteration. All terms are per-chip seconds.

Coefficients (matmul-flops conventions):
  train flops  = 8·N_active·T  (2 fwd + 4 bwd + 2 remat-refwd)   [remat on]
  prefill      = 2·N_active·T ; decode = 2·N_active·B
  attention    = 4·Hq·hd·Σpairs·mult, Σpairs: causal S²/2, window S·W,
                 decode B·S_cache; mult: train 4 (fwd+bwd+remat), else 1
  HBM train    = 38·N/chips (bf16 reads ×3 + f32 adam rw ×6 + grads)
                 + 24·L·T·D·2/chips (activation traffic, remat)
                 + 3·T·V·4/chips (chunked logits+loss fwd/bwd)
  HBM decode   = (2·N_active + KV cache + 3·B·V·4... logits)/chips
  collective   = ring all-reduce ≈ 2×payload:
    train: DP grads 2·(N/model)·gbytes + TP 12·L·(T/dp)·D·2 + logits T/dp·V·4
    decode: TP 4·L·(B/dp)·D·2 + logits (B/dp)·V·4
  ideal (fraction denominator's numerator): useful flops (6·N·T train /
    2·N·T else + attention at mult 3/1) vs unavoidable bytes (params+opt
    traffic; decode: params+KV read).
"""
from __future__ import annotations

import json

from repro.configs.base import SHAPES, ArchConfig, get_arch
from repro.launch.roofline import HW


def geometry(cfg: ArchConfig) -> dict:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        L_attn = cfg.n_layers
    elif fam == "hybrid":
        L_attn = cfg.n_layers // cfg.attn_every
    elif fam == "encdec":
        L_attn = cfg.n_enc_layers + 2 * cfg.n_layers  # self + cross
    else:
        L_attn = 0
    L_win = cfg.n_layers // 2 if cfg.alt_local_global else 0
    L_full = L_attn - L_win
    return {"L_attn": L_attn, "L_full": L_full, "L_win": L_win}


def analytic_cell(arch: str, shape_name: str, mesh: str, n_params: int,
                  n_active: int, *, bf16_grads: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    chips = 512 if mesh == "2x16x16" else 256
    model_par = 16
    dp = chips // model_par
    g = geometry(cfg)
    B, S = shape.global_batch, shape.seq_len
    Hq, hd, D, V, L = cfg.n_heads, cfg.hd, cfg.d_model, cfg.vocab_size, cfg.n_layers
    kind = shape.kind
    T = B * S if kind != "decode" else B
    gb = 2 if bf16_grads else 4

    if kind == "train":
        mult, c_p = 4, 8 if cfg.remat else 6
    elif kind == "prefill":
        mult, c_p = 1, 2
    else:
        mult, c_p = 1, 2

    # ---- flops ----
    flops = c_p * n_active * T
    if kind == "decode":
        pairs = B * S * (g["L_full"] + 0)  # every attn layer reads the cache
        pairs += B * min(S, cfg.sliding_window or S) * g["L_win"]
    else:
        pairs = B * S * S / 2 * g["L_full"] + \
            B * S * min(S, cfg.sliding_window or S) * g["L_win"]
    flops += 4 * Hq * hd * pairs * mult
    flops_useful = (6 if kind == "train" else 2) * n_active * T + \
        4 * Hq * hd * pairs * (3 if kind == "train" else 1)

    # ---- hbm bytes (per chip) ----
    if kind == "train":
        hbm = (38 * n_params + 24 * L * T * D * 2 + 3 * T * V * 4) / chips
        useful_bytes = (30 * n_params) / chips
    elif kind == "prefill":
        kv_bytes = g["L_attn"] * 2 * B * S * cfg.n_kv_heads * hd * 2
        hbm = (2 * n_active + 8 * L * T * D * 2 + kv_bytes + B * V * 4) / chips
        useful_bytes = (2 * n_active + kv_bytes) / chips
    else:
        kv_bytes = g["L_attn"] * 2 * B * S * cfg.n_kv_heads * hd * 2
        state_bytes = 0
        if cfg.ssm_state:
            d_inner = cfg.ssm_expand * D
            state_bytes = cfg.n_layers * B * (d_inner // cfg.ssm_headdim) * \
                cfg.ssm_headdim * cfg.ssm_state * 4
        if cfg.slstm_every:
            d_inner = int(cfg.proj_factor * D)
            P_ = d_inner // cfg.n_heads
            state_bytes = (L * 3 // 4) * B * cfg.n_heads * P_ * P_ * 4
        hbm = (2 * n_active + kv_bytes + state_bytes + 3 * B * V * 4) / chips
        useful_bytes = (2 * n_active + kv_bytes + state_bytes) / chips

    # ---- collective bytes (per chip) ----
    if kind == "train":
        coll = 2 * (n_params / model_par) * gb \
            + 12 * L * (T / dp) * D * 2 + (T / dp) * V * 4
    elif kind == "prefill":
        coll = 4 * L * (T / dp) * D * 2 + (B / min(dp, B)) * V * 4
    else:
        bloc = B / min(dp, B)
        coll = 4 * L * bloc * D * 2 + bloc * V * 4

    t_c = flops / chips / HW["peak_flops"]
    t_m = hbm / HW["hbm_bw"]
    t_x = coll / HW["link_bw"]
    bound = max(t_c, t_m, t_x)
    ideal = max(flops_useful / chips / HW["peak_flops"],
                useful_bytes / HW["hbm_bw"])
    dom = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(dom, key=dom.get)
    hints = {
        "compute": "cut remat re-compute (selective policies) / bigger MXU tiles",
        "memory": "shrink optimizer+activation traffic (ZeRO-3, fused kernels, "
                  "quantized KV)",
        "collective": "overlap TP all-reduces with compute; bf16/int8 grad "
                      "reduction; reduce-scatter+all-gather instead of all-reduce",
    }
    return {
        "an_flops": flops, "an_hbm_per_chip": hbm, "an_coll_per_chip": coll,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant, "bound_s": bound, "ideal_s": ideal,
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
        "useful_flops_ratio": flops_useful / max(flops, 1.0),
        "model_flops": (6 if kind == "train" else 2) * n_active * T,
        "hint": hints[dominant],
    }


def load_and_annotate(path: str = "experiments/dryrun.json") -> list[dict]:
    with open(path) as f:
        recs = json.load(f)
    for r in recs:
        if r.get("status") != "ok":
            continue
        r["analytic"] = analytic_cell(
            r["arch"], r["shape"], r["mesh"], r["n_params"],
            r["n_active_params"])
    return recs


def fmt_seconds(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | status | compile | bytes/device | "
             "HLO colls (AG/AR/RS/A2A/CP) |",
             "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") == "ok":
            by = r["collectives"]["by_kind"]
            cc = "/".join(str(by[k]["count"]) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
            mem = r["memory"].get("total_device_bytes", 0) / 2 ** 30
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                         f"{r.get('compile_s', 0):.0f}s | {mem:.2f} GiB | {cc} |")
        else:
            why = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['status']} | - | - | {why} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO flops | fraction | what would move it |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok" or r["mesh"] != mesh or "analytic" not in r:
            continue
        a = r["analytic"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_seconds(a['t_compute_s'])} | "
            f"{fmt_seconds(a['t_memory_s'])} | {fmt_seconds(a['t_collective_s'])} | "
            f"{a['dominant']} | {a['useful_flops_ratio']:.2f} | "
            f"{a['roofline_fraction']:.3f} | {a['hint']} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="experiments/dryrun.json")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load_and_annotate(args.inp)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single pod, analytic)\n")
    print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
