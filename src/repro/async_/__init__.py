"""Async serving pipeline substrate (DESIGN.md §10).

``executor``    : Future + the three executors (bounded ``WorkerPool``,
                  inline ``SerialExecutor``, deterministic ``StepExecutor``
                  test harness) and fault injection.
``coordinator`` : the cut → build-off-path → finalize-on-serving-thread
                  protocol used by async compaction and pooled retunes.
"""
from repro.async_.coordinator import (BackgroundBuild, BuildCoordinator,
                                      BuildFailure)
from repro.async_.executor import (FaultInjector, Future, InjectedCrash,
                                   PoolShutdown, SerialExecutor, StepExecutor,
                                   WorkerCrashed, WorkerPool)

__all__ = [
    "BackgroundBuild", "BuildCoordinator", "BuildFailure", "FaultInjector",
    "Future", "InjectedCrash", "PoolShutdown", "SerialExecutor",
    "StepExecutor", "WorkerCrashed", "WorkerPool",
]
