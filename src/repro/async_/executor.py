"""Execution substrate for the async serving pipeline (DESIGN.md §10).

Three executors behind one ``submit(fn, *args) -> Future`` interface:

  - ``WorkerPool``     : a bounded pool of daemon worker threads — the
                         production form. ``submit`` blocks once
                         ``max_pending`` tasks are queued (backpressure, so
                         a stalled device can never grow an unbounded flush
                         queue), a task that raises fails only its own
                         future, and ``shutdown`` drains or cancels
                         deterministically (no deadlock mid-flush: pending
                         futures either run or fail with ``PoolShutdown``).
  - ``SerialExecutor`` : runs every task inline at ``submit`` — the
                         ``sync=True`` baseline; async results must be
                         bit-identical to it.
  - ``StepExecutor``   : the test harness. Tasks only run when the caller
                         steps them, on the CALLING thread, in an order
                         drawn from a seeded rng — injectable worker
                         interleavings without thread nondeterminism, plus
                         explicit fault injection (``crash_next`` fails a
                         task with ``WorkerCrashed`` as if its worker died).

Fault injection for the real pool goes through ``hooks``: a callable run on
the worker immediately before each task; raising ``InjectedCrash`` kills
the worker thread mid-task (the task's future fails with ``WorkerCrashed``
and a replacement worker is spawned so capacity is preserved), any other
exception fails just the task. ``FaultInjector`` is the seeded standard
hook (crash every Nth task, or tasks whose label matches).
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Callable

import numpy as np

from repro.obs import NULL_OBSERVER

_PENDING = "pending"
_RUNNING = "running"
_DONE = "done"
_ERROR = "error"
_CANCELLED = "cancelled"


class WorkerCrashed(RuntimeError):
    """The worker executing this task died before completing it."""


class InjectedCrash(WorkerCrashed):
    """Raised by fault-injection hooks: kill the worker mid-task."""


class PoolShutdown(RuntimeError):
    """Submitted after shutdown, or cancelled by ``shutdown(cancel_pending=True)``."""


class Future:
    """Completion handle for one submitted task.

    Minimal by design (result/exception/wait/done + internal setters) so
    the deterministic harness can drive state transitions explicitly;
    ``result`` re-raises the task's exception, ``WorkerCrashed`` when the
    worker died, or ``PoolShutdown`` when the task was cancelled."""

    def __init__(self, label: str = "task"):
        self.label = label
        self._cond = threading.Condition()
        self._state = _PENDING
        self._result = None
        self._exc: BaseException | None = None
        self._callbacks: list[Callable] = []

    # ---- caller side ------------------------------------------------------

    @property
    def state(self) -> str:
        return self._state

    def done(self) -> bool:
        return self._state in (_DONE, _ERROR, _CANCELLED)

    def wait(self, timeout: float | None = None) -> bool:
        with self._cond:
            self._cond.wait_for(self.done, timeout)
            return self.done()

    def result(self, timeout: float | None = None):
        if not self.wait(timeout):
            raise TimeoutError(f"{self.label}: no result after {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: float | None = None) -> BaseException | None:
        if not self.wait(timeout):
            raise TimeoutError(f"{self.label}: still pending after {timeout}s")
        return self._exc

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        with self._cond:
            if not self.done():
                self._callbacks.append(cb)
                return
        cb(self)

    # ---- executor side ----------------------------------------------------

    def _set_running(self) -> bool:
        with self._cond:
            if self._state != _PENDING:
                return False
            self._state = _RUNNING
            return True

    def _finish(self, state: str, result=None, exc: BaseException | None = None) -> bool:
        with self._cond:
            if self.done():
                return False
            self._state, self._result, self._exc = state, result, exc
            callbacks, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for cb in callbacks:
            cb(self)
        return True

    def set_result(self, result) -> bool:
        return self._finish(_DONE, result=result)

    def set_exception(self, exc: BaseException) -> bool:
        return self._finish(_ERROR, exc=exc)

    def cancel(self, exc: BaseException | None = None) -> bool:
        return self._finish(_CANCELLED,
                            exc=exc or PoolShutdown(f"{self.label}: cancelled"))


class _Task:
    __slots__ = ("fn", "args", "future")

    def __init__(self, fn, args, future):
        self.fn, self.args, self.future = fn, args, future

    def run(self, hooks=None) -> None:
        """Execute on the current thread. ``InjectedCrash`` propagates to
        the caller (the worker loop turns it into a dead worker) AFTER
        failing this task's future with ``WorkerCrashed``."""
        if not self.future._set_running():
            return  # cancelled while queued
        try:
            if hooks is not None:
                hooks(self.future.label)
            result = self.fn(*self.args)
        except InjectedCrash as e:
            self.future.set_exception(
                WorkerCrashed(f"{self.future.label}: worker crashed ({e})"))
            raise
        except BaseException as e:  # noqa: BLE001 — task isolation boundary
            self.future.set_exception(e)
        else:
            self.future.set_result(result)


class FaultInjector:
    """Deterministic crash schedule for ``WorkerPool`` hooks: crashes the
    ``crash_on`` 1-indexed task(s), and/or every task whose label contains
    ``label_match``. Counting is global across workers (guarded)."""

    def __init__(self, crash_on: tuple[int, ...] = (),
                 label_match: str | None = None):
        self.crash_on = set(crash_on)
        self.label_match = label_match
        self.seen = 0
        self._lock = threading.Lock()

    def __call__(self, label: str) -> None:
        with self._lock:
            self.seen += 1
            n = self.seen
        if n in self.crash_on:
            raise InjectedCrash(f"scheduled crash at task #{n}")
        if self.label_match is not None and self.label_match in label:
            raise InjectedCrash(f"label match {self.label_match!r}")


def _task_kind(label: str) -> str:
    """Bounded-cardinality metric label: 'flush:size' -> 'flush',
    'retune@12.5' -> 'retune'."""
    return label.split(":", 1)[0].split("@", 1)[0]


def _observed_run(obs, task: _Task, hooks) -> None:
    """Run one task, reporting duration/count (and crash events) through
    the observer. Executors share this so pool threads and the seeded
    StepExecutor harness produce the same metric series."""
    if not obs.enabled:
        task.run(hooks)
        return
    kind = _task_kind(task.future.label)
    t0 = time.perf_counter()
    try:
        task.run(hooks)
    except InjectedCrash:
        obs.event("worker_crash", label=task.future.label)
        raise
    finally:
        obs.observe("executor_task_ms", (time.perf_counter() - t0) * 1e3,
                    kind=kind)
        obs.counter("executor_tasks", kind=kind)


def drive_until(executor, future: Future, timeout: float | None = None) -> bool:
    """Wait for ``future`` to complete. On a caller-driven executor (one
    with a ``drive()`` method, i.e. the StepExecutor harness) this RUNS
    pending tasks — in the executor's seeded order — instead of blocking,
    so a drain/wait from serving code can never deadlock the harness."""
    drive = getattr(executor, "drive", None)
    if drive is not None:
        while not future.done():
            if not drive():
                break
    return future.wait(timeout)


class SerialExecutor:
    """Inline execution at submit — the sync baseline (and the degenerate
    executor for environments without threads)."""

    def __init__(self, hooks: Callable[[str], None] | None = None,
                 observer=None):
        self.hooks = hooks
        self.obs = observer if observer is not None else NULL_OBSERVER
        self.submitted = 0
        self.order: list[str] = []  # labels in execution order

    def submit(self, fn, *args, label: str = "task") -> Future:
        fut = Future(label)
        self.submitted += 1
        self.order.append(label)
        try:
            _observed_run(self.obs, _Task(fn, args, fut), self.hooks)
        except InjectedCrash:
            pass  # future already failed with WorkerCrashed
        return fut

    def inflight(self) -> int:
        return 0

    def shutdown(self, wait: bool = True, cancel_pending: bool = False) -> None:
        pass


class WorkerPool:
    """Bounded thread pool with crash isolation and clean shutdown."""

    _STOP = object()

    def __init__(self, workers: int = 2, max_pending: int | None = 256,
                 name: str = "pool", hooks: Callable[[str], None] | None = None,
                 observer=None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.name = name
        self.hooks = hooks
        self.obs = observer if observer is not None else NULL_OBSERVER
        self._queue: queue.Queue = queue.Queue(maxsize=max_pending or 0)
        self._lock = threading.Lock()
        self._closed = False
        self._inflight = 0            # queued or running tasks
        self._idle = threading.Condition(self._lock)
        self._threads: list[threading.Thread] = []
        self._tid = itertools.count()
        self.crashed_workers = 0
        for _ in range(workers):
            self._spawn()

    def _spawn(self) -> None:
        t = threading.Thread(target=self._worker, daemon=True,
                             name=f"{self.name}-{next(self._tid)}")
        self._threads.append(t)
        t.start()

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is self._STOP:
                return
            try:
                _observed_run(self.obs, item, self.hooks)
            except InjectedCrash:
                # this worker is "dead": replace it so capacity survives a
                # crash, unless the pool is already shutting down
                with self._lock:
                    self.crashed_workers += 1
                    self._threads.remove(threading.current_thread())
                    if not self._closed:
                        self._spawn()
                    self._task_done()
                return
            with self._lock:
                self._task_done()

    def _task_done(self) -> None:
        self._inflight -= 1
        if self._inflight == 0:
            self._idle.notify_all()

    def submit(self, fn, *args, label: str = "task") -> Future:
        with self._lock:
            if self._closed:
                raise PoolShutdown(f"{self.name}: submit after shutdown")
            self._inflight += 1
        fut = Future(label)
        try:
            self._queue.put(_Task(fn, args, fut))  # blocks at max_pending
        except BaseException:
            with self._lock:
                self._task_done()
            raise
        # a shutdown may have slipped between the closed-check and the put,
        # landing this task BEHIND the stop sentinels where no worker will
        # ever pop it: cancel the future so waiters fail with PoolShutdown
        # instead of hanging. Completion is single-shot, so if a worker DID
        # get to the task first the cancel is a no-op — and if the cancel
        # wins, the worker (or shutdown's drain) still accounts the task.
        if self._closed:
            fut.cancel(PoolShutdown(f"{self.name}: shut down during submit"))
        return fut

    def inflight(self) -> int:
        return self._inflight

    def join(self, timeout: float | None = None) -> bool:
        """Wait until no task is queued or running."""
        with self._idle:
            return self._idle.wait_for(lambda: self._inflight == 0, timeout)

    def shutdown(self, wait: bool = True, cancel_pending: bool = False) -> None:
        """Idempotent. ``cancel_pending`` fails queued-but-unstarted futures
        with ``PoolShutdown`` instead of running them; running tasks always
        finish (workers only check the stop sentinel between tasks), so a
        shutdown mid-flush never deadlocks and never abandons a future."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = list(self._threads)
        if cancel_pending:
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if item is self._STOP:
                    continue
                if item.future.cancel():
                    with self._lock:
                        self._task_done()
        for _ in threads:
            self._queue.put(self._STOP)
        if wait:
            for t in threads:
                t.join()
            # tasks a racing submit() enqueued behind the sentinels have no
            # worker left: cancel and account them so join() can't hang
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if item is self._STOP:
                    continue
                item.future.cancel(PoolShutdown(f"{self.name}: shut down"))
                with self._lock:
                    self._task_done()

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=True)


class StepExecutor:
    """Deterministic harness executor: nothing runs until stepped.

    ``submit`` only queues; ``run_next()`` executes ONE task on the calling
    thread — by explicit index, or drawn from the seeded rng (uniform over
    the queue) so a test seed fully determines the interleaving. Determinism
    holds because tasks in this system are pure builds/flushes whose
    *completion order* is the only scheduling freedom; running them on the
    caller serializes memory effects while still permuting that order."""

    def __init__(self, seed: int | None = None,
                 hooks: Callable[[str], None] | None = None,
                 observer=None):
        self.rng = np.random.default_rng(seed)
        self.seeded = seed is not None
        self.hooks = hooks
        self.obs = observer if observer is not None else NULL_OBSERVER
        self._pending: list[_Task] = []
        self._closed = False
        self.ran: list[str] = []  # labels in the order they executed

    def submit(self, fn, *args, label: str = "task") -> Future:
        if self._closed:
            raise PoolShutdown("StepExecutor: submit after shutdown")
        fut = Future(label)
        self._pending.append(_Task(fn, args, fut))
        return fut

    def pending(self) -> list[str]:
        return [t.future.label for t in self._pending]

    def inflight(self) -> int:
        return len(self._pending)

    def _pick(self, index: int | None) -> _Task:
        if index is None:
            index = int(self.rng.integers(len(self._pending))) if self.seeded else 0
        return self._pending.pop(index)

    def run_next(self, index: int | None = None) -> Future:
        if not self._pending:
            raise IndexError("StepExecutor: nothing pending")
        task = self._pick(index)
        try:
            _observed_run(self.obs, task, self.hooks)
        except InjectedCrash:
            pass
        self.ran.append(task.future.label)
        return task.future

    def run_all(self) -> list[Future]:
        out = []
        while self._pending:
            out.append(self.run_next())
        return out

    def drive(self) -> bool:
        """Make progress on behalf of a blocking waiter: run ONE pending
        task (seeded order). Blocking waits (batcher drain, coordinator
        wait) call this so the deterministic harness can't deadlock —
        the interleaving stays fully determined by the seed."""
        if not self._pending:
            return False
        self.run_next()
        return True

    def crash_next(self, index: int | None = None) -> Future:
        """Fail one pending task as if its worker died mid-run."""
        if not self._pending:
            raise IndexError("StepExecutor: nothing pending")
        task = self._pick(index)
        task.future._set_running()
        task.future.set_exception(
            WorkerCrashed(f"{task.future.label}: worker crashed (injected)"))
        self.obs.event("worker_crash", label=task.future.label)
        self.ran.append(task.future.label)
        return task.future

    def shutdown(self, wait: bool = True, cancel_pending: bool = False) -> None:
        self._closed = True
        if cancel_pending:
            pending, self._pending = self._pending, []
            for t in pending:
                t.future.cancel()
        elif wait:
            self.run_all()
