"""Background-build coordinator (DESIGN.md §10).

The protocol every off-path rebuild in this codebase follows — async
compaction, pooled drift retunes, per-tenant loops:

  1. *cut* on the serving thread (cheap, under the serving locks): snapshot
     whatever the build needs;
  2. *build* on the executor (slow, PURE — touches no serving state, takes
     no serving locks, so a busy pool can never deadlock against a thread
     holding the batcher lock);
  3. *finalize* back on a serving thread, from ``poll()`` inside the tick
     loop (or ``wait()``): the atomic swap, under whatever locks the caller
     takes inside its finalize callback.

The coordinator enforces at most one in-flight build per key, records
failures without poisoning serving (a failed build is dropped and listed in
``failures``; finalize never runs for it), and keeps completion
deterministic under the StepExecutor harness: builds complete exactly when
the test steps them, and finalize runs exactly at the next ``poll``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from repro.async_.executor import Future, drive_until


@dataclass
class BackgroundBuild:
    """One in-flight (or finished) background build."""

    key: object
    label: str
    future: Future
    finalize: object                 # Callable[[build result, now], event]
    t_submit: float
    event: object | None = None      # finalize's return value
    error: BaseException | None = None
    finalized: bool = False

    @property
    def built(self) -> bool:
        return self.future.done()

    def wait(self, timeout: float | None = None) -> bool:
        """Wait for the BUILD (not the finalize) to complete."""
        return self.future.wait(timeout)


@dataclass
class BuildFailure:
    key: object
    label: str
    error: BaseException
    t: float


class BuildCoordinator:
    """At most one in-flight background build per key."""

    def __init__(self, executor):
        self.executor = executor
        self._inflight: dict[object, BackgroundBuild] = {}
        self.completed: list[BackgroundBuild] = []
        self.failures: list[BuildFailure] = []
        # serializes the pop phase: two threads polling concurrently must
        # never both claim (and finalize) the same completed build
        self._lock = threading.Lock()

    def inflight(self, key: object = None) -> bool:
        if key is None:
            return bool(self._inflight)
        return key in self._inflight

    def submit(self, key: object, build_fn, finalize,
               label: str | None = None,
               now: float | None = None) -> BackgroundBuild | None:
        """Launch ``build_fn`` on the executor unless ``key`` already has a
        build in flight (returns None — the caller's trigger will re-fire).
        ``finalize(result, now)`` runs later, on the thread that polls."""
        with self._lock:
            if key in self._inflight:
                return None
            build = BackgroundBuild(
                key=key, label=label or f"build:{key}",
                future=self.executor.submit(build_fn,
                                            label=label or f"build:{key}"),
                finalize=finalize,
                t_submit=time.time() if now is None else now)
            self._inflight[key] = build
        return build

    def poll(self, now: float | None = None) -> list[BackgroundBuild]:
        """Finalize every completed build ON THIS THREAD. Returns the
        builds finalized by this call; build errors are recorded in
        ``failures`` (serving continues on the old state), finalize errors
        propagate to the caller — they mean the swap itself is broken."""
        with self._lock:
            done = [b for b in self._inflight.values() if b.built]
            for build in done:
                del self._inflight[build.key]
        out = []
        for build in done:
            exc = build.future.exception()
            if exc is not None:
                build.error = exc
                self.failures.append(BuildFailure(
                    key=build.key, label=build.label, error=exc,
                    t=time.time() if now is None else now))
                continue
            build.event = build.finalize(build.future.result(), now)
            build.finalized = True
            self.completed.append(build)
            out.append(build)
        return out

    def wait(self, key: object = None, timeout: float | None = None,
             now: float | None = None) -> list[BackgroundBuild]:
        """Block until the build(s) complete, then finalize them here."""
        with self._lock:
            if key is not None:
                builds = [self._inflight[key]] if key in self._inflight else []
            else:
                builds = list(self._inflight.values())
        for b in builds:
            if not drive_until(self.executor, b.future, timeout):
                raise TimeoutError(f"{b.label}: build still running "
                                   f"after {timeout}s")
        return self.poll(now)

    def stats(self) -> dict:
        return {"inflight": len(self._inflight),
                "completed": len(self.completed),
                "failures": len(self.failures)}
