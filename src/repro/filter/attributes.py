"""Per-row attribute columns for filtered search (DESIGN.md §12).

``AttributeStore`` holds one packed array per field, indexed by the SAME
stable item ids the ingest layer hands out (``ingest/table.py``): base row
ids, delta-segment ids, and post-compaction ids all index the same arrays,
so attributes survive rebases for free.

Field vocabulary (after redisvl's schema kinds):
  * ``tag``      — categorical string; stored as int32 vocab codes,
                   ``-1`` = missing. Unknown query values encode to a
                   never-matching code.
  * ``numeric``  — float32, ``NaN`` = missing (NaN compares false under
                   every Eq/Range, which is exactly the missing-never-
                   matches rule).
  * ``texthash`` — free text matched by equality only; stored as a
                   deterministic 64-bit blake2b hash (int64), int64-min =
                   missing.

Columns grow geometrically as ids arrive. Host evaluation
(``bitmap``) and device evaluation (``device_bitmap``) share one AST
walker parameterised by the array namespace, so they agree bit-for-bit —
the hypothesis property test in tests/test_filter.py leans on that.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.filter.predicate import And, Eq, In, Not, Or, Predicate, Range

TAG, NUMERIC, TEXTHASH = "tag", "numeric", "texthash"
_KINDS = (TAG, NUMERIC, TEXTHASH)

_TAG_MISSING = np.int32(-1)
_TAG_NEVER = np.int32(-2)          # encode() result for unknown query values
_HASH_MISSING = np.int64(np.iinfo(np.int64).min)


def text_hash(value) -> np.int64:
    """Deterministic 64-bit hash of a string (blake2b, not PYTHONHASHSEED)."""
    h = hashlib.blake2b(str(value).encode("utf-8"), digest_size=8).digest()
    v = np.int64(int.from_bytes(h, "little", signed=True))
    if v == _HASH_MISSING:  # pragma: no cover - 2^-64 corner
        v = np.int64(_HASH_MISSING + 1)
    return v


@dataclass(frozen=True)
class FieldSpec:
    name: str
    kind: str  # tag | numeric | texthash

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown field kind {self.kind!r} (want {_KINDS})")


class AttributeStore:
    """Packed per-field columns keyed by stable item id."""

    def __init__(self, fields, capacity: int = 0):
        self.fields: dict[str, FieldSpec] = {}
        for f in fields:
            spec = f if isinstance(f, FieldSpec) else FieldSpec(*f)
            self.fields[spec.name] = spec
        self._cols: dict[str, np.ndarray] = {
            name: self._empty(spec.kind, capacity)
            for name, spec in self.fields.items()}
        self._vocab: dict[str, dict] = {
            name: {} for name, spec in self.fields.items() if spec.kind == TAG}
        self.version = 0            # bumps on every put(); caches key on it
        self._device: dict[str, tuple] = {}   # field -> (version, jnp column)

    # ---- storage ----------------------------------------------------------

    @staticmethod
    def _empty(kind: str, n: int) -> np.ndarray:
        if kind == TAG:
            return np.full(n, _TAG_MISSING, dtype=np.int32)
        if kind == NUMERIC:
            return np.full(n, np.nan, dtype=np.float32)
        return np.full(n, _HASH_MISSING, dtype=np.int64)

    @property
    def capacity(self) -> int:
        return next(iter(self._cols.values())).shape[0] if self._cols else 0

    def _ensure(self, upto: int) -> None:
        cap = self.capacity
        if upto <= cap:
            return
        new = max(upto, 2 * cap, 64)
        for name, spec in self.fields.items():
            grown = self._empty(spec.kind, new)
            grown[:cap] = self._cols[name]
            self._cols[name] = grown

    def encode(self, field: str, value, grow: bool = False):
        """Scalar encoding of a query/storage value for ``field``.

        Tag values unseen at storage time encode to a never-matching code
        (query side), or get a fresh vocab code (``grow=True``, put side)."""
        spec = self.fields[field]
        if spec.kind == NUMERIC:
            return np.float32(value)
        if spec.kind == TEXTHASH:
            return text_hash(value)
        vocab = self._vocab[field]
        code = vocab.get(value)
        if code is None:
            if not grow:
                return _TAG_NEVER
            code = np.int32(len(vocab))
            vocab[value] = code
        return np.int32(code)

    def put(self, ids, values: dict) -> None:
        """Write attribute values for rows ``ids``.

        ``values`` maps field name -> sequence. Sequences longer than
        ``ids`` are truncated (mutation resolution can shrink a batch,
        e.g. upserts against a small live pool); shorter is an error.
        Unknown field names raise. Bumps ``version``."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0 or not values:
            return
        self._ensure(int(ids.max()) + 1)
        for name, vals in values.items():
            spec = self.fields.get(name)
            if spec is None:
                raise KeyError(f"unknown attribute field {name!r}")
            vals = list(vals) if not isinstance(vals, np.ndarray) else vals
            if len(vals) < ids.size:
                raise ValueError(
                    f"field {name!r}: {len(vals)} values for {ids.size} ids")
            col = self._cols[name]
            if spec.kind == NUMERIC:
                col[ids] = np.asarray(vals[:ids.size], dtype=np.float32)
            else:
                col[ids] = [self.encode(name, v, grow=True)
                            for v in vals[:ids.size]]
        self.version += 1
        self._device.clear()

    def take(self, field: str, ids) -> np.ndarray:
        """Encoded values of ``field`` for stable ids (host).

        Ids beyond the stored capacity read as missing — rows inserted
        without attributes simply never match positive predicates."""
        col = self._cols[field]
        ids = np.asarray(ids, dtype=np.int64)
        out = self._empty(self.fields[field].kind, ids.size)
        ok = (ids >= 0) & (ids < col.shape[0])
        out[ok] = col[ids[ok]]
        return out

    def device_column(self, field: str):
        """Device copy of the packed column, cached per ``version``."""
        import jax.numpy as jnp

        hit = self._device.get(field)
        if hit is not None and hit[0] == self.version:
            return hit[1]
        col = jnp.asarray(self._cols[field])
        self._device[field] = (self.version, col)
        return col

    # ---- evaluation -------------------------------------------------------

    def bitmap(self, pred: Predicate, ids) -> np.ndarray:
        """Host bool bitmap: does each of ``ids`` match ``pred``?"""
        ids = np.asarray(ids, dtype=np.int64)
        cache: dict[str, np.ndarray] = {}

        def take(field):
            if field not in cache:
                cache[field] = self.take(field, ids)
            return cache[field]

        return self._eval(pred, take, np)

    def device_bitmap(self, pred: Predicate, ids):
        """Device bool bitmap over stable ids — identical semantics to
        :meth:`bitmap` (same walker, jnp namespace); feeds kernel
        ``keep_mask`` operands."""
        import jax.numpy as jnp

        ids = jnp.asarray(np.asarray(ids, dtype=np.int64))
        cache: dict = {}

        def take(field):
            if field not in cache:
                col = self.device_column(field)
                n = col.shape[0]
                ok = (ids >= 0) & (ids < n)
                vals = col[jnp.clip(ids, 0, max(n - 1, 0))] if n else None
                miss = self._empty(self.fields[field].kind, 1)[0]
                if n == 0:
                    cache[field] = jnp.full(ids.shape, miss)
                else:
                    cache[field] = jnp.where(ok, vals, miss)
            return cache[field]

        return self._eval(pred, take, jnp)

    def _eval(self, pred, take, xp):
        if isinstance(pred, Eq):
            return take(pred.field) == self.encode(pred.field, pred.value)
        if isinstance(pred, In):
            col = take(pred.field)
            out = xp.zeros(col.shape, dtype=bool)
            for v in pred.values:
                out = out | (col == self.encode(pred.field, v))
            return out
        if isinstance(pred, Range):
            if self.fields[pred.field].kind != NUMERIC:
                raise TypeError(f"Range on non-numeric field {pred.field!r}")
            col = take(pred.field)
            ok = ~xp.isnan(col)
            if pred.lo is not None:
                ok = ok & (col >= np.float32(pred.lo))
            if pred.hi is not None:
                ok = ok & (col <= np.float32(pred.hi))
            return ok
        if isinstance(pred, (And, Or)):
            if not pred.children:
                raise ValueError(f"{type(pred).__name__}() needs children")
            out = None
            for c in pred.children:
                b = self._eval(c, take, xp)
                if out is None:
                    out = b
                else:
                    out = (out & b) if isinstance(pred, And) else (out | b)
            return out
        if isinstance(pred, Not):
            return ~self._eval(pred.child, take, xp)
        raise TypeError(f"not a predicate node: {pred!r}")


def synth_attributes(n_rows: int, seed: int = 0, n_categories: int = 8,
                     sources: int = 4) -> AttributeStore:
    """Standard synthetic attribute set for benches / traces / tests:
    a skewed ``category`` tag, a uniform [0,1) ``score`` numeric (quantile
    ranges over it hit any target selectivity), and a small-pool ``source``
    texthash."""
    rng = np.random.default_rng(seed)
    attrs = AttributeStore([
        FieldSpec("category", TAG),
        FieldSpec("score", NUMERIC),
        FieldSpec("source", TEXTHASH),
    ], capacity=n_rows)
    # zipf-ish categorical skew: p(c) ∝ 1/(c+1)
    w = 1.0 / (np.arange(n_categories) + 1.0)
    cats = rng.choice(n_categories, size=n_rows, p=w / w.sum())
    attrs.put(np.arange(n_rows), {
        "category": [f"cat{c}" for c in cats],
        "score": rng.random(n_rows).astype(np.float32),
        "source": [f"src{int(s)}" for s in rng.integers(0, sources, n_rows)],
    })
    return attrs
