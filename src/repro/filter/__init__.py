"""Filtered multi-vector search: attribute store, predicate AST,
selectivity estimation (DESIGN.md §12)."""
from repro.filter.attributes import (NUMERIC, TAG, TEXTHASH, AttributeStore,
                                     FieldSpec, synth_attributes, text_hash)
from repro.filter.predicate import (And, Eq, In, Not, Or, Predicate, Range,
                                    describe)
from repro.filter.selectivity import (BITMAP_COST, GATHER_OVERHEAD,
                                      SelectivityEstimator, inflate_eks,
                                      masked_scan_cost, prefilter_cost)

__all__ = [
    "AttributeStore", "FieldSpec", "synth_attributes", "text_hash",
    "TAG", "NUMERIC", "TEXTHASH",
    "Predicate", "Eq", "In", "Range", "And", "Or", "Not", "describe",
    "SelectivityEstimator", "inflate_eks", "masked_scan_cost",
    "prefilter_cost", "GATHER_OVERHEAD", "BITMAP_COST",
]
