"""Predicate AST for filtered search (DESIGN.md §12).

Six node types — ``Eq`` / ``In`` / ``Range`` / ``And`` / ``Or`` / ``Not`` —
all frozen, hashable dataclasses, so a predicate object can sit directly
inside plan-cache keys (``online/plancache.py::PlanKey``) and plan-group
keys (``serve/compiler.py::GroupKey``) without a separate fingerprint.

Semantics (missing values):
  * A row that is missing a field NEVER matches ``Eq`` / ``In`` / ``Range``
    on that field.
  * ``Not`` is the pure boolean complement — ``Not(Eq(f, v))`` therefore
    DOES match rows missing ``f``. Host bitmaps and device masks agree on
    this by construction (both evaluate leaves first, then complement).

Evaluation lives in ``AttributeStore.bitmap`` (host, numpy) and
``AttributeStore.device_bitmap`` (device, jnp) so encodings (tag vocab,
text hashing) stay next to the packed columns.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Predicate:
    """Marker base class for AST nodes."""

    def fields(self) -> frozenset:
        """Attribute field names referenced anywhere in this tree."""
        return frozenset(_collect_fields(self))


@dataclass(frozen=True)
class Eq(Predicate):
    field: str
    value: object


@dataclass(frozen=True)
class In(Predicate):
    field: str
    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))


@dataclass(frozen=True)
class Range(Predicate):
    """Inclusive numeric range ``lo <= v <= hi``; ``None`` = unbounded.

    Both bounds ``None`` matches every row with a (non-missing) value."""
    field: str
    lo: float | None = None
    hi: float | None = None


@dataclass(frozen=True, init=False)
class And(Predicate):
    children: tuple

    def __init__(self, *children):
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True, init=False)
class Or(Predicate):
    children: tuple

    def __init__(self, *children):
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Not(Predicate):
    child: Predicate


def _collect_fields(node) -> list:
    if isinstance(node, (Eq, In, Range)):
        return [node.field]
    if isinstance(node, (And, Or)):
        out = []
        for c in node.children:
            out.extend(_collect_fields(c))
        return out
    if isinstance(node, Not):
        return _collect_fields(node.child)
    raise TypeError(f"not a predicate node: {node!r}")


def describe(pred) -> str:
    """Compact human-readable form for logs / bench labels."""
    if pred is None:
        return "*"
    if isinstance(pred, Eq):
        return f"{pred.field}=={pred.value!r}"
    if isinstance(pred, In):
        return f"{pred.field} in {list(pred.values)!r}"
    if isinstance(pred, Range):
        lo = "-inf" if pred.lo is None else f"{pred.lo:g}"
        hi = "+inf" if pred.hi is None else f"{pred.hi:g}"
        return f"{pred.field} in [{lo},{hi}]"
    if isinstance(pred, And):
        return "(" + " & ".join(describe(c) for c in pred.children) + ")"
    if isinstance(pred, Or):
        return "(" + " | ".join(describe(c) for c in pred.children) + ")"
    if isinstance(pred, Not):
        return f"!{describe(pred.child)}"
    raise TypeError(f"not a predicate node: {pred!r}")
