"""Sampled selectivity estimation + access-path cost model (DESIGN.md §12).

The planner chooses between three access paths per (query, predicate):

  pre     gather the matching rows, brute-force only those.
          cost ≈ dim(q)·sel·N·(1 + GATHER_OVERHEAD) + BITMAP_COST·N
  masked  full fused scan with the keep bitmap composed into the kernel's
          row mask.   cost ≈ dim(q)·N + BITMAP_COST·N
  post    unfiltered index probe with eks inflated by 1/sel, candidates
          filtered afterwards (``core/planner.py::_plan_cost(selectivity=)``).

With GATHER_OVERHEAD = 1 a gathered row costs twice a streamed row
(scattered DMA reads full cache lines / HBM bursts regardless of use), so
pre and masked cross at sel = 1 / (1 + GATHER_OVERHEAD) = 0.5: pre wins
clearly at percent-level selectivities, masked/post from ~50% up. The
same constant drives ``launch/roofline.py::modeled_scan_bytes``'s
``gather_amplification`` so the byte model and the planner tell one story.

Costs are in the paper's unit (dim-weighted distance computations);
BITMAP_COST charges the attribute-column pass that every filtered path
pays once per row.
"""
from __future__ import annotations

import math

import numpy as np

GATHER_OVERHEAD = 1.0   # extra cost per gathered row vs streamed row
BITMAP_COST = 1.0       # bitmap evaluation, per row, in dim-units


def prefilter_cost(qdim: float, n_rows: float, sel: float) -> float:
    return qdim * sel * n_rows * (1.0 + GATHER_OVERHEAD) + BITMAP_COST * n_rows


def masked_scan_cost(qdim: float, n_rows: float) -> float:
    return qdim * n_rows + BITMAP_COST * n_rows


def inflate_eks(eks, sel: float, n_rows: int) -> list:
    """Post-filter over-fetch: ek/sel so ~ek survivors remain after the
    filter, capped at the table size."""
    floor = 1.0 / max(float(n_rows), 1.0)
    s = max(float(sel), floor)
    return [min(int(math.ceil(ek / s)), int(n_rows)) if ek > 0 else 0
            for ek in eks]


class SelectivityEstimator:
    """Uniform row-sample selectivity estimate with add-half smoothing.

    ``estimate(pred)`` evaluates ``pred``'s bitmap over a fixed seeded
    sample of live ids and returns (hits + 0.5) / (n + 1) — never exactly
    0 or 1, so the planner stays defined; exact-zero matches are caught by
    the engine's bitmap guard, not the estimator. Results are cached per
    (predicate, attribute version); ``refresh`` re-samples after churn."""

    def __init__(self, attrs, ids, sample_size: int = 512, seed: int = 0):
        self.attrs = attrs
        self.sample_size = int(sample_size)
        self.seed = int(seed)
        self._draws = 0
        self._cache: dict = {}
        self.refresh(ids)

    def refresh(self, ids) -> None:
        ids = np.asarray(ids, dtype=np.int64)
        rng = np.random.default_rng(self.seed + self._draws)
        self._draws += 1
        take = min(self.sample_size, ids.size)
        self.sample = (np.sort(rng.choice(ids, size=take, replace=False))
                       if take else ids)
        self._cache.clear()

    def estimate(self, pred) -> float:
        if pred is None:
            return 1.0
        key = (pred, self.attrs.version)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        n = self.sample.size
        if n == 0:
            return 1.0
        hits = int(self.attrs.bitmap(pred, self.sample).sum())
        est = (hits + 0.5) / (n + 1.0)
        if len(self._cache) > 4096:
            self._cache.clear()
        self._cache[key] = est
        return est
