"""Query-plan execution engine bridging MINT plans to the TPU-native path.

A MINT plan (X, EK) executes as: per-index scan (IVF-Flat / flat via the
fused distance+top-k kernels) → candidate union → full-score rerank. The
CPU-reference path (graph indexes, numpy) lives in ``core.tuner.execute_plan``;
this engine is the batched, jit-friendly serving form used by the serving
example and the distributed dry-run.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.types import Query, QueryPlan
from repro.data.vectors import MultiVectorDatabase
from repro.kernels.distance.ops import fused_scan


def execute_plan_fused(db: MultiVectorDatabase, query: Query, plan: QueryPlan,
                       interpret: bool | None = None):
    """Run a plan with the fused kernels (flat scans at each index's ek)."""
    cands = []
    cost = 0.0
    for spec, ek in zip(plan.indexes, plan.eks):
        data = db.concat(spec.vid)
        q = query.concat(spec.vid)[None, :]
        _, ids = fused_scan(jnp.asarray(q), jnp.asarray(data),
                            k=min(ek, data.shape[0]), interpret=interpret)
        cands.append(np.asarray(ids)[0])
        cost += data.shape[1] * data.shape[0]  # numDist = N for a flat scan
    if not cands:
        data = db.concat(query.vid)
        q = query.concat()[None, :]
        _, ids = fused_scan(jnp.asarray(q), jnp.asarray(data), k=query.k,
                            interpret=interpret)
        return np.asarray(ids)[0], query.dim() * db.n_rows
    union = np.unique(np.concatenate(cands))
    scores = db.concat(query.vid)[union] @ query.concat()
    cost += query.dim() * sum(plan.eks)
    top = np.argsort(-scores, kind="stable")[: query.k]
    return union[top], cost
