"""DEPRECATED shim — plan execution moved to ``repro.serve.engine``.

``execute_plan_fused`` used to dispatch one ``fused_scan`` per query per
index and unconditionally added the rerank term (diverging from
``planner._plan_cost`` and ``core.tuner.execute_plan`` on single
exact-vid plans). It now delegates to the batched serving engine
(``serve.engine.BatchEngine``) as a batch of one, which applies the
single-exact-vid no-rerank fast path and the ek==0 filtering
consistently with the planner's cost model. New code should construct a
``BatchEngine`` and serve whole batches — that is the single execution
path for plans.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core.types import Query, QueryPlan
from repro.data.vectors import MultiVectorDatabase


def execute_plan_fused(db: MultiVectorDatabase, query: Query, plan: QueryPlan,
                       interpret: bool | None = None):
    """Run one plan with the fused kernels (flat scans at each index's ek).

    Deprecated: one-query convenience over ``BatchEngine``; batch your
    (query, plan) pairs through ``BatchEngine.search_batch`` instead.
    """
    warnings.warn(
        "repro.search.engine.execute_plan_fused is deprecated; use "
        "repro.serve.engine.BatchEngine (batched plan-group execution)",
        DeprecationWarning, stacklevel=2)
    from repro.serve.engine import BatchEngine
    eng = BatchEngine(db, store=None, interpret=interpret)
    ids, cost = eng.execute_plan_single(query, plan)
    return np.asarray(ids), cost
