"""Distributed vector-search serving (DESIGN.md §3, §5).

The database rows are sharded across the data-parallel axis; every device
scans its shard with the fused distance+top-k path (the Pallas kernels on
TPU; their jnp oracle elsewhere) and only the per-shard top-k (k values +
global ids) crosses the network — a tournament merge, never raw rows.

``search_step`` is jit/lower-able with ShapeDtypeStructs, so the same
multi-pod dry-run methodology applies to the serving plane (reported as an
extra, beyond-the-40-cells row in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


from repro.kernels.topk.kernel import NEG_INF


def _local_scan(db_shard, qvecs, k, shard_offset, valid_n=None, bad=None):
    scores = qvecs @ db_shard.T                       # (Q, N_local)
    masked = None
    if valid_n is not None:
        # rows at global index >= valid_n are column-store padding
        gids = shard_offset + jnp.arange(db_shard.shape[0])
        masked = (gids >= valid_n)[None, :]
    if bad is not None:
        # per-row bad mask (tombstones ∪ ¬predicate), sharded like db rows
        b = bad.astype(bool)[None, :]
        masked = b if masked is None else (masked | b)
    if masked is not None:
        scores = jnp.where(masked, NEG_INF, scores)
    vals, idx = jax.lax.top_k(scores, k)
    idx = idx + shard_offset
    if masked is not None:
        # masked tail slots report id 0 (same contract as the fused
        # kernels) so downstream stable-id gathers never index padding
        idx = jnp.where(vals <= NEG_INF / 2, 0, idx)
    return vals, idx


def make_search_step(mesh: Mesh, k: int, axis: str = "data",
                     valid_n: int | None = None, masked: bool = False):
    """Returns search_step(db_shard_view, qvecs) -> (vals (Q,k), ids (Q,k)).

    db is laid out (N, d) sharded on axis 0 over ``axis``; queries are
    replicated. The merge all-gathers only (Q, k) candidates per shard.
    ``valid_n`` marks trailing rows as column-store padding (masked out),
    so the serving engine can scan pre-padded device-resident columns.
    ``masked=True`` adds a third operand ``bad`` — a (N,) row bitmap
    (True/1 = tombstoned or filtered out), sharded exactly like the rows —
    so mesh cells mask in-cell instead of over-fetching past dead rows and
    score-killing them on the host. Bad rows come back at NEG_INF with id
    0, matching the fused-kernel contract.
    """
    n_shards = mesh.shape[axis]

    def step(db, qvecs, bad=None):
        def shard_fn(db_local, q_local, *rest):
            rank = jax.lax.axis_index(axis)
            n_local = db_local.shape[0]
            vals, ids = _local_scan(db_local, q_local, min(k, db_local.shape[0]),
                                    rank * n_local, valid_n=valid_n,
                                    bad=rest[0] if rest else None)
            # tournament merge: gather candidates only
            all_vals = jax.lax.all_gather(vals, axis)   # (S, Q, k)
            all_ids = jax.lax.all_gather(ids, axis)
            S, Q, kk = all_vals.shape
            flat_v = jnp.moveaxis(all_vals, 0, 1).reshape(Q, S * kk)
            flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(Q, S * kk)
            best_v, pos = jax.lax.top_k(flat_v, k)
            best_i = jnp.take_along_axis(flat_i, pos, axis=1)
            return best_v, best_i

        spec_db = P(axis, None)
        spec_q = P()
        in_specs = (spec_db, spec_q) + ((P(axis),) if masked else ())
        args = (db, qvecs) + ((bad,) if masked else ())
        # outputs are bitwise-identical on every shard after the gather +
        # top_k, but replication-rule inference can't see that — disable the check
        return shard_map(shard_fn, mesh=mesh,
                         in_specs=in_specs,
                         out_specs=(P(), P()), check_rep=False)(*args)

    return step


def distributed_rerank(mesh: Mesh, db, cand_ids, qvec, k: int,
                       axis: str = "data"):
    """Full-score rerank of candidate ids against a sharded database:
    each shard scores the candidates it owns; a masked all-reduce merges."""
    n_shards = mesh.shape[axis]

    def shard_fn(db_local, ids, q):
        rank = jax.lax.axis_index(axis)
        n_local = db_local.shape[0]
        local = ids - rank * n_local
        mine = (local >= 0) & (local < n_local)
        rows = db_local[jnp.clip(local, 0, n_local - 1)]
        scores = rows @ q
        scores = jnp.where(mine, scores, 0.0)
        scores = jax.lax.psum(scores, axis)  # exactly one shard owns each id
        return scores

    scores = shard_map(shard_fn, mesh=mesh,
                       in_specs=(P(axis, None), P(), P()),
                       out_specs=P(), check_rep=False)(db, cand_ids, qvec)
    vals, pos = jax.lax.top_k(scores, k)
    return vals, cand_ids[pos]
