"""Flash attention (forward) Pallas kernel for the embedding-model substrate.

Online-softmax over KV blocks: grid (B, Hq, Sq/bq, Skv/bkv) with the KV axis
sequential; running (m, l, acc) live in VMEM scratch. GQA is free via the
K/V BlockSpec index map (h -> h // group) — no KV repetition in memory.
Supports causal masking, sliding windows (Gemma-2 local layers), and attn
logit softcapping. Masked-out blocks are computed-and-masked (a production
TPU kernel would skip them via the grid; noted in EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, pad_to, tpu_compiler_params

NEG_INF = float(-3.0e38)


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  n_kv_blocks: int, bq: int, bkv: int, sq: int, skv: int,
                  causal: bool, window: int, softcap: float, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)      # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)      # (bkv, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    # positions: q rows are aligned to the END of the kv sequence
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + (skv - sq)
    kpos = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < skv  # padding guard
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bkv", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    scale: float | None = None, bq: int = 128, bkv: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d) -> (B, Hq, Sq, d)."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale_f = float(scale if scale is not None else d ** -0.5)

    qp = pad_to(q, 2, bq)
    kp = pad_to(k, 2, bkv)
    vp = pad_to(v, 2, bkv)
    Sqp, Skvp = qp.shape[2], kp.shape[2]
    grid = (B, Hq, Sqp // bq, Skvp // bkv)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, n_kv_blocks=grid[3], bq=bq, bkv=bkv, sq=Sq, skv=Skv,
            causal=causal, window=window, softcap=softcap, scale=scale_f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]
