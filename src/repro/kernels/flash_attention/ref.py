"""Pure-jnp oracle for flash attention (GQA, causal, sliding window, softcap)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float | None = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d). Hq % Hkv == 0.

    window > 0 limits attention to the last ``window`` positions (inclusive
    of self); q positions are aligned to the END of the kv sequence
    (q index i attends up to kv index Skv - Sq + i when causal).
    """
    B, Hq, Sq, d = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    Skv = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
