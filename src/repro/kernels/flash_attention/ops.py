"""Jitted public op for flash attention."""
from repro.kernels.flash_attention.kernel import flash_attention

__all__ = ["flash_attention"]
