"""Streaming top-k Pallas kernel.

Reduces (B, N) scores to per-row top-k without materializing a sort:
grid (B/bm, N/bn) with the column axis sequential; a running (bm, k)
value/index buffer lives in the output blocks (same index_map for every
column step — the standard TPU accumulation idiom). Each column block is
folded in by k rounds of (max, argmax, mask) — vectorized across rows, no
in-kernel sort required (Mosaic-friendly). The wrapper does a final
lax.top_k over (B, k) to order the buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, pad_to, tpu_compiler_params

NEG_INF = float(-3.0e38)


def neg_inf_for(dtype) -> float:
    """Masking/padding sentinel pinned per score dtype: the most negative
    FINITE value exactly representable in ``dtype`` that still lands at or
    below ``NEG_INF`` after the kernel's cast to f32 — or -inf when the
    dtype has no finite value that low (f16 tops out at -65504, far ABOVE
    the f32 buffer init, so a finite f16 sentinel would beat the empty
    buffer slots and let a masked row surface as a real candidate).
    Writing raw ``NEG_INF`` into a narrow dtype instead leaves the sentinel
    to the dtype's rounding — bf16 happens to round it away from zero
    today, but that is luck, not a contract."""
    dt = jnp.dtype(dtype)
    if dt == jnp.dtype(jnp.float32):
        return NEG_INF
    fi = jnp.finfo(dt)
    lo = float(fi.min)
    return lo if lo <= NEG_INF else float("-inf")


def _topk_kernel(scores_ref, vals_ref, idxs_ref, *, k: int, bn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idxs_ref[...] = jnp.zeros_like(idxs_ref)

    s = scores_ref[...].astype(jnp.float32)          # (bm, bn)
    bm = s.shape[0]
    col_ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    iota_bn = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)

    def fold(_, carry):
        s, vals, idxs = carry
        m = jnp.max(s, axis=1)                        # (bm,)
        am = jnp.argmax(s, axis=1)                    # (bm,)
        sel = iota_bn == am[:, None]
        cid = jnp.sum(jnp.where(sel, col_ids, 0), axis=1)
        vmin = jnp.min(vals, axis=1)
        pmin = jnp.argmin(vals, axis=1)
        improve = m > vmin                            # (bm,)
        hit = improve[:, None] & (iota_k == pmin[:, None])
        vals = jnp.where(hit, m[:, None], vals)
        idxs = jnp.where(hit, cid[:, None], idxs)
        s = jnp.where(sel, NEG_INF, s)
        return s, vals, idxs

    s, vals, idxs = jax.lax.fori_loop(
        0, k, fold, (s, vals_ref[...], idxs_ref[...]))
    vals_ref[...] = vals
    idxs_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "interpret"))
def topk_scores(scores: jnp.ndarray, k: int, bm: int = 128, bn: int = 512,
                interpret: bool | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, N) -> per-row (values, indices) of the k best, best first."""
    if interpret is None:
        interpret = default_interpret()
    B, N = scores.shape
    k_eff = min(k, N)
    sp = pad_to(pad_to(scores, 0, bm), 1, bn,
                value=neg_inf_for(scores.dtype))
    Bp, Np = sp.shape
    grid = (Bp // bm, Np // bn)

    vals, idxs = pl.pallas_call(
        functools.partial(_topk_kernel, k=k_eff, bn=bn),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k_eff), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(sp)
    vals, idxs = vals[:B], idxs[:B]
    order_vals, order_pos = jax.lax.top_k(vals, k_eff)
    idxs = jnp.take_along_axis(idxs, order_pos, axis=1)
    return order_vals, idxs
