"""Pure-jnp oracle for blockwise top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ref(scores: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, N) -> (values (B, k), indices (B, k)), best first."""
    return jax.lax.top_k(scores.astype(jnp.float32), k)
