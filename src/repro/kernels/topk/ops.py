"""Jitted public op for streaming top-k."""
from repro.kernels.topk.kernel import topk_scores

__all__ = ["topk_scores"]
