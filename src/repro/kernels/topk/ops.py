"""Jitted public op for streaming top-k."""
from repro.kernels.topk.kernel import neg_inf_for, topk_scores

__all__ = ["neg_inf_for", "topk_scores"]
