"""Shared Pallas kernel utilities.

TPU v5e is the compilation target (MXU 128×128, VMEM ~16MiB); on this CPU
container every kernel runs through ``interpret=True``, which executes the
kernel body in Python and validates indexing/semantics exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Version-portable pltpu compiler params (renamed TPUCompilerParams ->
    CompilerParams across jax releases)."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def pad_to(x: jnp.ndarray, axis: int, multiple: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
