"""Jitted public op for the streaming fused scan (one launch, no score
matrix). See ``kernels/streaming/kernel.py`` for the kernel itself."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, pad_to, tpu_compiler_params
from repro.kernels.streaming.kernel import streaming_kernel


def _bad_mask(n_padded: int, valid_n, dead_mask,
              keep_mask=None) -> jnp.ndarray:
    """(1, n_padded) f32 0/1 row mask: 1 = padding past ``valid_n`` (a
    TRACED scalar — no per-table-size recompiles), tombstoned, or filtered
    out by ``keep_mask`` (predicate bitmap, True = row matches). The
    keep ∧ ¬dead composition happens here, so predicate masking rides the
    same in-register (1, N) row operand as tombstones."""
    bad = jnp.arange(n_padded, dtype=jnp.int32) >= valid_n
    if dead_mask is not None:
        bad = bad | pad_to(dead_mask.astype(bool), 0, n_padded)[:n_padded]
    if keep_mask is not None:
        # pad_to pads with 0 = False = not kept, so padded rows stay bad
        bad = bad | ~pad_to(keep_mask.astype(bool), 0, n_padded)[:n_padded]
    return bad.astype(jnp.float32)[None, :]


@functools.partial(jax.jit, static_argnames=(
    "k", "metric", "bm", "bn", "bk", "interpret"))
def streaming_fused_scan(q: jnp.ndarray, db: jnp.ndarray, k: int,
                         metric: str = "dot",
                         valid_n=None, dead_mask: jnp.ndarray | None = None,
                         delta: jnp.ndarray | None = None,
                         delta_valid_n=None,
                         delta_dead_mask: jnp.ndarray | None = None,
                         keep_mask: jnp.ndarray | None = None,
                         delta_keep_mask: jnp.ndarray | None = None,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         interpret: bool | None = None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, d) queries over (N, d) base rows — plus an optional (Nd, d)
    delta source — -> top-k (values, ids) in ONE kernel launch, never
    materializing the (B, N) score matrix.

    ``valid_n`` / ``delta_valid_n`` are TRACED scalars (rows at or past
    them are masked in-register); ``dead_mask`` / ``delta_dead_mask`` are
    per-source tombstone bitmaps; ``keep_mask`` / ``delta_keep_mask`` are
    per-source predicate bitmaps (True = row matches the filter) composed
    into the same (1, N) row-mask operand. Ids are combined-physical: base row i is
    id i; delta row r is id ``db.shape[0] + r`` (callers map delta ids back
    with the padded base row count). When fewer than k live rows exist the
    tail slots come back at NEG_INF with id 0, exactly like the two-pass
    path — callers drop them by score.

    k is clamped to the combined (padded) row count only; callers that
    need the two-pass ``min(k, valid_n)`` narrowing clamp before calling
    (``valid_n`` may be traced here, so it cannot shape the output).
    """
    if interpret is None:
        interpret = default_interpret()
    B, d = q.shape
    Nb, d2 = db.shape
    assert d == d2, (d, d2)
    has_delta = delta is not None
    Nd = delta.shape[0] if has_delta else 0
    if has_delta:
        assert delta.shape[1] == d, (delta.shape, d)

    qsq = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    bsq = jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)[None, :]

    qp = pad_to(pad_to(q, 0, bm), 1, bk)
    dbp = pad_to(pad_to(db, 0, bn), 1, bk)
    qsqp = pad_to(qsq, 0, bm, value=1.0)
    bsqp = pad_to(bsq, 1, bn, value=1.0)
    Bp, dp = qp.shape
    Nbp = dbp.shape[0]
    nbt = Nbp // bn

    valid_b = Nb if valid_n is None else valid_n
    bbad = pad_to(_bad_mask(Nbp, valid_b, dead_mask, keep_mask),
                  1, bn, value=1.0)

    k_eff = min(k, Nb + Nd)
    operands = [qp, dbp]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
        pl.BlockSpec((bn, bk),
                     lambda i, j, kb: (jnp.minimum(j, nbt - 1), kb)),
    ]
    if has_delta:
        dsq = jnp.sum(delta.astype(jnp.float32) ** 2, axis=-1)[None, :]
        dltp = pad_to(pad_to(delta, 0, bn), 1, bk)
        Ndp = dltp.shape[0]
        ndt = Ndp // bn
        valid_d = Nd if delta_valid_n is None else delta_valid_n
        dbad = pad_to(_bad_mask(Ndp, valid_d, delta_dead_mask,
                                delta_keep_mask),
                      1, bn, value=1.0)
        dsqp = pad_to(dsq, 1, bn, value=1.0)
        operands += [dltp, qsqp, bsqp, dsqp, bbad, dbad]
        in_specs += [
            pl.BlockSpec((bn, bk),
                         lambda i, j, kb: (jnp.maximum(j - nbt, 0), kb)),
            pl.BlockSpec((bm, 1), lambda i, j, kb: (i, 0)),
            pl.BlockSpec((1, bn),
                         lambda i, j, kb: (0, jnp.minimum(j, nbt - 1))),
            pl.BlockSpec((1, bn),
                         lambda i, j, kb: (0, jnp.maximum(j - nbt, 0))),
            pl.BlockSpec((1, bn),
                         lambda i, j, kb: (0, jnp.minimum(j, nbt - 1))),
            pl.BlockSpec((1, bn),
                         lambda i, j, kb: (0, jnp.maximum(j - nbt, 0))),
        ]
    else:
        ndt = 0
        operands += [qsqp, bsqp, bbad]
        in_specs += [
            pl.BlockSpec((bm, 1), lambda i, j, kb: (i, 0)),
            pl.BlockSpec((1, bn),
                         lambda i, j, kb: (0, jnp.minimum(j, nbt - 1))),
            pl.BlockSpec((1, bn),
                         lambda i, j, kb: (0, jnp.minimum(j, nbt - 1))),
        ]

    grid = (Bp // bm, nbt + ndt, dp // bk)
    vals, idxs = pl.pallas_call(
        functools.partial(
            streaming_kernel, n_base_tiles=nbt, n_k_blocks=grid[2], bn=bn,
            k=k_eff, metric=metric, delta_id_offset=Nbp,
            has_delta=has_delta),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, k_eff), lambda i, j, kb: (i, 0)),
            pl.BlockSpec((bm, k_eff), lambda i, j, kb: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k_eff), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)
    vals, idxs = vals[:B], idxs[:B]
    order_vals, order_pos = jax.lax.top_k(vals, k_eff)
    idxs = jnp.take_along_axis(idxs, order_pos, axis=1)
    return order_vals, idxs


__all__ = ["streaming_fused_scan"]
