"""Two-pass reference oracle for the streaming fused scan.

Materializes the full score matrix with the ORIGINAL two-pass kernels
(``kernels/distance`` + ``kernels/topk``), applies the pad/tombstone masks
as elementwise passes, and reduces with the blockwise top-k kernel — the
exact computation the streaming kernel replaces. Score values are computed
with the same per-tile f32 accumulation (pass the same ``bk``), so for
distinct scores the streaming kernel must match this oracle bit-for-bit.
Ids use the same combined-physical convention (delta row r -> padded base
rows + r)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import pad_to
from repro.kernels.distance.kernel import batched_scores
from repro.kernels.topk.kernel import NEG_INF, topk_scores


def _masked_scores(q, db, metric, valid_n, dead_mask, bk, interpret,
                   keep_mask=None):
    scores = batched_scores(q, db, metric=metric, bk=bk, interpret=interpret)
    n = db.shape[0]
    bad = jnp.arange(n) >= (n if valid_n is None else valid_n)
    if dead_mask is not None:
        bad = bad | pad_to(dead_mask.astype(bool), 0, n)[:n]
    if keep_mask is not None:
        bad = bad | ~pad_to(keep_mask.astype(bool), 0, n)[:n]
    return jnp.where(bad[None, :], NEG_INF, scores)


def streaming_fused_scan_ref(q, db, k, metric="dot", valid_n=None,
                             dead_mask=None, delta=None, delta_valid_n=None,
                             delta_dead_mask=None, keep_mask=None,
                             delta_keep_mask=None, bk: int = 128,
                             bn: int = 128,
                             interpret: bool | None = None):
    """(values, ids) with the streaming op's exact output contract, via the
    two-pass path. ``bn`` is only used to compute the combined-id offset
    (the padded base row count)."""
    scores = _masked_scores(q, db, metric, valid_n, dead_mask, bk, interpret,
                            keep_mask)
    total = db.shape[0]
    if delta is not None:
        dscores = _masked_scores(q, delta, metric, delta_valid_n,
                                 delta_dead_mask, bk, interpret,
                                 delta_keep_mask)
        # combined-id space: delta ids are offset by the PADDED base rows,
        # matching the streaming kernel; pad the base side's score block so
        # column positions line up with those ids
        base_padded = pad_to(scores, 1, bn, value=NEG_INF)
        scores = jnp.concatenate([base_padded, dscores], axis=1)
        total = db.shape[0] + delta.shape[0]
        k_eff = min(k, total)
        vals, idxs = topk_scores(scores, k_eff, interpret=interpret)
        # un-pad the id space is NOT needed: ids < base_padded width are
        # base-physical, ids >= it are (padded offset + delta row) already
        return vals, idxs
    return topk_scores(scores, min(k, total), interpret=interpret)
