"""Streaming fused-scan Pallas kernel: distance + online top-k, one launch.

The two-pass design (``kernels/distance`` then ``kernels/topk``) writes the
full (B, N) score matrix to HBM, re-reads it for masking, and re-reads it
again for top-k — O(B·N) score bytes of HBM traffic on the hottest path in
the repo, and a hard cap on table size per dispatch. This kernel is the
memory-efficient-attention trick applied to search: stream row tiles of the
database through VMEM, compute each tile's scores on the MXU, apply padding
and tombstone masks in-register, and fold the tile into a running per-query
(k-best values, ids) buffer that lives in the revisited output blocks. The
score matrix never exists; HBM score traffic drops to O(B·k).

Grid: (B/bm, n_base_tiles + n_delta_tiles, d/bk), row-tile and d axes
sequential. A second (delta) row source rides the SAME grid: tiles past
``n_base_tiles`` read the delta operand instead of the base via piecewise
BlockSpec index maps (the inactive operand's block index is clamped, so the
pipeline never re-fetches it), which is how ``BatchEngine`` merges base +
delta-segment candidates in ONE launch instead of two dispatches + a host
merge. Delta rows report combined ids offset by the padded base row count.

Masking is in-register: per-source "bad" row masks (padding beyond
``valid_n`` ∪ tombstones) arrive as (1, N) f32 0/1 operands built by the
jitted wrapper from a TRACED ``valid_n`` — no per-table-size recompiles —
and masked columns are scored NEG_INF before the fold, so they can never
claim a top-k slot (strict-improvement fold + NEG_INF buffer init).

Tie-break contract: the fold extracts block maxima first-match-wins
(lowest column id within a tile) and only a STRICT improvement replaces a
buffer slot, so for distinct scores the result is bit-identical to the
two-pass oracle; equal-score ties follow ascending fold order exactly like
the two-pass top-k kernel. Sentinel ties (masked rows) never enter the
buffer in either path. The wrapper's final ``lax.top_k`` ordering pass is
identical to the two-pass wrapper's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk.kernel import NEG_INF


def streaming_kernel(*refs, n_base_tiles: int, n_k_blocks: int, bn: int,
                     k: int, metric: str, delta_id_offset: int,
                     has_delta: bool):
    """Kernel body. Operand order (delta refs only when ``has_delta``):
    q, base, [delta], qsq, basesq, [deltasq], base_bad, [delta_bad] ->
    (vals, idxs) outputs + one (bm, bn) f32 accumulator scratch."""
    if has_delta:
        (q_ref, db_ref, dlt_ref, qsq_ref, bsq_ref, dsq_ref,
         bbad_ref, dbad_ref, vals_ref, idxs_ref, acc_ref) = refs
    else:
        (q_ref, db_ref, qsq_ref, bsq_ref, bbad_ref,
         vals_ref, idxs_ref, acc_ref) = refs
    j = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when((j == 0) & (kb == 0))
    def _init_topk():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idxs_ref[...] = jnp.zeros_like(idxs_ref)

    @pl.when(kb == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    in_base = j < n_base_tiles
    q = q_ref[...].astype(jnp.float32)
    db = db_ref[...].astype(jnp.float32)
    if has_delta:
        db = jnp.where(in_base, db, dlt_ref[...].astype(jnp.float32))
    acc_ref[...] += jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == n_k_blocks - 1)
    def _fold_tile():
        acc = acc_ref[...]
        if has_delta:
            dbsq = jnp.where(in_base, bsq_ref[...], dsq_ref[...])
            bad = jnp.where(in_base, bbad_ref[...], dbad_ref[...])
        else:
            dbsq = bsq_ref[...]
            bad = bbad_ref[...]
        # metric epilogue — identical formulas to kernels/distance
        if metric == "dot":
            s = acc
        elif metric == "cosine":
            qn = jnp.sqrt(jnp.maximum(qsq_ref[...], 1e-24))   # (bm, 1)
            dn = jnp.sqrt(jnp.maximum(dbsq, 1e-24))           # (1, bn)
            s = acc / (qn * dn)
        else:  # l2 -> negative squared distance
            s = -(qsq_ref[...] - 2.0 * acc + dbsq)
        s = jnp.where(bad > 0, NEG_INF, s)                    # in-register mask

        bm = s.shape[0]
        iota_bn = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)
        # combined physical id: base tiles count from 0, delta tiles from
        # delta_id_offset (= padded base rows; masked base padding can
        # never collide — it never claims a slot)
        local_j = jnp.where(in_base, j, j - n_base_tiles)
        offset = jnp.where(in_base, 0, delta_id_offset)
        col_ids = offset + local_j * bn + iota_bn

        def fold(_, carry):
            s, vals, idxs = carry
            m = jnp.max(s, axis=1)                            # (bm,)
            am = jnp.argmax(s, axis=1)                        # first max wins
            sel = iota_bn == am[:, None]
            cid = jnp.sum(jnp.where(sel, col_ids, 0), axis=1)
            vmin = jnp.min(vals, axis=1)
            pmin = jnp.argmin(vals, axis=1)
            improve = m > vmin                                # strict only
            hit = improve[:, None] & (iota_k == pmin[:, None])
            vals = jnp.where(hit, m[:, None], vals)
            idxs = jnp.where(hit, cid[:, None], idxs)
            s = jnp.where(sel, NEG_INF, s)
            return s, vals, idxs

        _, vals, idxs = jax.lax.fori_loop(
            0, k, fold, (s, vals_ref[...], idxs_ref[...]))
        vals_ref[...] = vals
        idxs_ref[...] = idxs
