"""Streaming fused scan: distance + online top-k in ONE Pallas kernel."""
from repro.kernels.streaming.ops import streaming_fused_scan
from repro.kernels.streaming.ref import streaming_fused_scan_ref

__all__ = ["streaming_fused_scan", "streaming_fused_scan_ref"]
