"""Jitted public ops for the two-pass fused scan: scores + top-k.

This is the REFERENCE path: it materializes the full (B, N) score matrix
in HBM between the distance and top-k kernels. The serving default is the
single-launch ``kernels/streaming`` kernel (same results, no score
matrix); ``BatchEngine(streaming=False)`` or ``REPRO_TWOPASS_SCAN=1``
falls back here, and the parity tests hold the streaming kernel
bit-identical to this composition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to
from repro.kernels.distance.kernel import batched_scores
from repro.kernels.topk.kernel import NEG_INF, topk_scores


@jax.jit
def _mask_rows(scores: jnp.ndarray, valid_n, dead, keep=None) -> jnp.ndarray:
    """ONE fused elementwise pass over the score matrix: rows at or past
    ``valid_n`` (padding), tombstoned rows, and rows outside the predicate
    ``keep`` bitmap all go to NEG_INF in a single ``jnp.where``.
    ``valid_n`` is a TRACED scalar — every live-row
    count shares one compiled program (the old static-argnum version
    recompiled per table size and burned an extra full (B, N) HBM
    read/write per mask). ``dead`` / ``keep`` are None (structural —
    compiles a variant without that mask) or (N,) bool bitmaps."""
    bad = jnp.arange(scores.shape[1]) >= valid_n
    if dead is not None:
        bad = bad | dead
    if keep is not None:
        bad = bad | ~keep
    return jnp.where(bad[None, :], NEG_INF, scores)


def fused_scan(q: jnp.ndarray, db: jnp.ndarray, k: int, metric: str = "dot",
               valid_n: int | None = None,
               dead_mask: jnp.ndarray | None = None,
               keep_mask: jnp.ndarray | None = None,
               interpret: bool | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The TPU-native index scan: (B, d) queries over (N, d) rows -> top-k
    (values, indices). Composition of the MXU distance kernel and the
    streaming top-k kernel; this is exactly MINT's cost unit
    (numDist = N, cost = dim * N) realized as hardware matmuls.

    ``valid_n`` supports pre-padded device-resident databases (the serving
    column store): rows at index >= valid_n are padding and are masked to
    -inf so they can never win a top-k slot; k is clamped to valid_n.

    ``dead_mask`` is the mutation layer's tombstone bitmap — an (N,) device
    bool array, True for deleted rows. Tombstoned rows are score-masked to
    -inf between the distance and top-k kernels, so a deleted item can
    never surface in a result: when fewer than k rows are alive, the tail
    slots come back at NEG_INF and the caller drops them. The rows are
    still scanned (cost accounting is unchanged) — reclaiming the scan work
    itself is the compactor's job, not the mask's.

    ``keep_mask`` is the filter layer's predicate bitmap (True = row
    matches); non-matching rows are masked to -inf in the same fused pass
    as padding and tombstones (keep ∧ ¬dead composition)."""
    scores = batched_scores(q, db, metric=metric, interpret=interpret)
    has_pad = valid_n is not None and valid_n < db.shape[0]
    if has_pad:
        k = min(k, int(valid_n))
    if has_pad or dead_mask is not None or keep_mask is not None:
        vn = db.shape[0] if valid_n is None else valid_n
        keep = None
        if keep_mask is not None:
            n = db.shape[0]
            keep = pad_to(keep_mask.astype(bool), 0, n)[:n]
        scores = _mask_rows(scores, vn, dead_mask, keep)
    return topk_scores(scores, k, interpret=interpret)


__all__ = ["batched_scores", "fused_scan"]
