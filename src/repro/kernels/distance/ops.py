"""Jitted public ops for the distance kernel: fused scan = scores + top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.distance.kernel import batched_scores
from repro.kernels.topk.kernel import topk_scores


def fused_scan(q: jnp.ndarray, db: jnp.ndarray, k: int, metric: str = "dot",
               interpret: bool | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The TPU-native index scan: (B, d) queries over (N, d) rows -> top-k
    (values, indices). Composition of the MXU distance kernel and the
    streaming top-k kernel; this is exactly MINT's cost unit
    (numDist = N, cost = dim * N) realized as hardware matmuls."""
    scores = batched_scores(q, db, metric=metric, interpret=interpret)
    return topk_scores(scores, k, interpret=interpret)


__all__ = ["batched_scores", "fused_scan"]
