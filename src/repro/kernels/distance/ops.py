"""Jitted public ops for the distance kernel: fused scan = scores + top-k."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance.kernel import batched_scores
from repro.kernels.topk.kernel import NEG_INF, topk_scores


@functools.partial(jax.jit, static_argnames=("valid_n",))
def _mask_pad_rows(scores: jnp.ndarray, valid_n: int) -> jnp.ndarray:
    pad = jnp.arange(scores.shape[1]) >= valid_n
    return jnp.where(pad[None, :], NEG_INF, scores)


@jax.jit
def _mask_dead_rows(scores: jnp.ndarray, dead: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(dead[None, :], NEG_INF, scores)


def fused_scan(q: jnp.ndarray, db: jnp.ndarray, k: int, metric: str = "dot",
               valid_n: int | None = None,
               dead_mask: jnp.ndarray | None = None,
               interpret: bool | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The TPU-native index scan: (B, d) queries over (N, d) rows -> top-k
    (values, indices). Composition of the MXU distance kernel and the
    streaming top-k kernel; this is exactly MINT's cost unit
    (numDist = N, cost = dim * N) realized as hardware matmuls.

    ``valid_n`` supports pre-padded device-resident databases (the serving
    column store): rows at index >= valid_n are padding and are masked to
    -inf so they can never win a top-k slot; k is clamped to valid_n.

    ``dead_mask`` is the mutation layer's tombstone bitmap — an (N,) device
    bool array, True for deleted rows. Tombstoned rows are score-masked to
    -inf between the distance and top-k kernels, so a deleted item can
    never surface in a result: when fewer than k rows are alive, the tail
    slots come back at NEG_INF and the caller drops them. The rows are
    still scanned (cost accounting is unchanged) — reclaiming the scan work
    itself is the compactor's job, not the mask's."""
    scores = batched_scores(q, db, metric=metric, interpret=interpret)
    if valid_n is not None and valid_n < db.shape[0]:
        scores = _mask_pad_rows(scores, int(valid_n))
        k = min(k, int(valid_n))
    if dead_mask is not None:
        scores = _mask_dead_rows(scores, dead_mask)
    return topk_scores(scores, k, interpret=interpret)


__all__ = ["batched_scores", "fused_scan"]
