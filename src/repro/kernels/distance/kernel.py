"""Fused batched-score Pallas kernel (the MINT distance hot spot on TPU).

An IVF/flat index scan is exactly this kernel: Q (B, d) against a row block
DB (N, d), producing (B, N) similarity scores on the MXU. Tiled as a
K-accumulated matmul: grid (B/bm, N/bn, d/bk) with a VMEM f32 accumulator;
the metric epilogue (dot / cosine / −L2²) runs on the final K step.

Block shapes default to MXU-aligned (128, 128, 128(d)) and are overridable
for the shape sweep tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, pad_to, tpu_compiler_params


def _distance_kernel(q_ref, db_ref, qsq_ref, dbsq_ref, out_ref, acc_ref, *,
                     n_k_blocks: int, metric: str):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    db = db_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == n_k_blocks - 1)
    def _epilogue():
        acc = acc_ref[...]
        if metric == "dot":
            out = acc
        elif metric == "cosine":
            qn = jnp.sqrt(jnp.maximum(qsq_ref[...], 1e-24))   # (bm, 1)
            dn = jnp.sqrt(jnp.maximum(dbsq_ref[...], 1e-24))  # (1, bn)
            out = acc / (qn * dn)
        else:  # l2 -> negative squared distance
            out = -(qsq_ref[...] - 2.0 * acc + dbsq_ref[...])
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("metric", "bm", "bn", "bk", "interpret"))
def batched_scores(q: jnp.ndarray, db: jnp.ndarray, metric: str = "dot",
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool | None = None) -> jnp.ndarray:
    """(B, d) x (N, d) -> (B, N) scores via the Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    B, d = q.shape
    N, d2 = db.shape
    assert d == d2, (d, d2)

    qsq = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)   # (B, 1)
    dbsq = jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)[None, :]       # (1, N)

    qp = pad_to(pad_to(q, 0, bm), 1, bk)
    dbp = pad_to(pad_to(db, 0, bn), 1, bk)
    qsqp = pad_to(qsq, 0, bm, value=1.0)
    dbsqp = pad_to(dbsq, 1, bn, value=1.0)
    Bp, dp = qp.shape
    Np = dbp.shape[0]
    grid = (Bp // bm, Np // bn, dp // bk)

    out = pl.pallas_call(
        functools.partial(_distance_kernel, n_k_blocks=grid[2], metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, dbp, qsqp, dbsqp)
    return out[:B, :N]
