"""Pure-jnp oracle for the fused batched score kernel."""
from __future__ import annotations

import jax.numpy as jnp


def batched_scores_ref(q: jnp.ndarray, db: jnp.ndarray,
                       metric: str = "dot") -> jnp.ndarray:
    """q: (B, d); db: (N, d) -> (B, N) scores (higher = more similar)."""
    q32 = q.astype(jnp.float32)
    db32 = db.astype(jnp.float32)
    if metric == "dot":
        return q32 @ db32.T
    if metric == "cosine":
        qn = q32 / jnp.maximum(jnp.linalg.norm(q32, axis=-1, keepdims=True), 1e-12)
        dn = db32 / jnp.maximum(jnp.linalg.norm(db32, axis=-1, keepdims=True), 1e-12)
        return qn @ dn.T
    if metric == "l2":
        # negative squared distance so "higher is better" everywhere
        q2 = jnp.sum(q32 * q32, axis=-1, keepdims=True)
        d2 = jnp.sum(db32 * db32, axis=-1)
        return -(q2 - 2.0 * (q32 @ db32.T) + d2[None, :])
    raise ValueError(f"unknown metric {metric!r}")
