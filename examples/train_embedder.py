"""Train an embedding-tower LM (reduced config) with the fault-tolerant
loop: checkpoints, a simulated mid-run failure, and resume.

    PYTHONPATH=src python examples/train_embedder.py [--arch qwen2-7b]
"""
import argparse
import shutil

from repro.configs.base import get_arch
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt", default="/tmp/repro_train_example")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)
    cfg = get_arch(args.arch).reduced()
    tcfg = TrainConfig(steps=args.steps, batch=8, seq_len=128,
                       ckpt_dir=args.ckpt, ckpt_every=10, peak_lr=1e-3)

    tripped = {"done": False}

    def chaos(step):  # one injected node failure mid-run
        if step == args.steps // 2 and not tripped["done"]:
            tripped["done"] = True
            print(f"!! injecting node failure at step {step} "
                  f"(loop will restore the latest checkpoint)")
            return True
        return False

    res = train(cfg, tcfg, fail_injector=chaos)
    print(f"arch={args.arch} (reduced) steps={res.final_step} "
          f"restarts={res.restarts}")
    print(f"loss: first={res.losses[0]:.3f} last={res.losses[-1]:.3f}")
    assert res.losses[-1] < res.losses[0], "loss should decrease"
    print("ok: trained through a failure with checkpoint/restore")


if __name__ == "__main__":
    main()
