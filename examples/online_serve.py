"""Online serving walkthrough: steady traffic → drift → background retune.

Tunes for a "day" workload, serves it through the micro-batching runtime
(plan cache keeps the planner off the hot path), then lets the traffic
drift to "night" columns: the workload monitor detects the drift, the
background re-tuner re-runs MINT on the observed window, shadow-builds the
new configuration, and atomically swaps it in — watch the served cost drop.

    PYTHONPATH=src python examples/online_serve.py
"""
import numpy as np

from repro.core.types import Constraints, Workload
from repro.core.tuner import Mint
from repro.data.vectors import make_database, make_queries
from repro.online import OnlineRuntime, RuntimeConfig, diurnal_trace, steady_trace


def main():
    db = make_database(5000, [("image", 64), ("title", 48), ("audio", 80),
                              ("content", 64)], seed=2)
    day_qs = make_queries(db, [(0,), (0, 1), (1,)], k=10, seed=0)
    night_qs = make_queries(db, [(2,), (2, 3), (3,)], k=10, seed=1)
    day = Workload(queries=day_qs, probs=np.ones(3))
    night = Workload(queries=night_qs, probs=np.ones(3))
    cons = Constraints(theta_recall=0.85, theta_storage=3)

    mint = Mint(db, index_kind="ivf", seed=0)
    rt = OnlineRuntime(db, mint, day, cons, config=RuntimeConfig(
        max_batch=8, max_delay_ms=5.0, window=64, min_window=32,
        drift_threshold=0.35, cooldown_s=0.02, measure=True))
    print("tuned (day):", sorted(s.name for s in rt.result.configuration))

    steady = steady_trace(db, day, n=64, qps=1000.0, seed=3)
    tickets = rt.run_trace(steady)
    st = rt.stats()
    print(f"steady: {len(tickets)} queries in {st['batcher']['batches']} "
          f"micro-batches (mean {st['batcher']['mean_batch']:.1f}/batch), "
          f"plan-cache hit rate {st['plan_cache']['hit_rate']:.2f}, "
          f"mean cost {np.mean([t.metrics.cost for t in tickets]) / 1e3:.0f}K")

    drift = diurnal_trace(db, day, night, n=128, qps=1000.0, seed=4,
                          t0=1.0, qid_start=10_000)
    tickets = rt.run_trace(drift)
    for ev in rt.retune_events:
        print(f"retune @t={ev.t:.3f}s: drift={ev.drift:.2f} -> generation "
              f"{ev.generation}, est cost {ev.est_cost_before / 1e3:.0f}K -> "
              f"{ev.est_cost_after / 1e3:.0f}K ({ev.built} built, "
              f"{ev.dropped} dropped, tune {ev.tune_seconds * 1e3:.0f}ms)")
    print("serving (night):", sorted(s.name for s in rt.result.configuration))
    tail = tickets[-32:]
    head = tickets[:32]
    print(f"drift head: mean cost {np.mean([t.metrics.cost for t in head]) / 1e3:.0f}K"
          f"  recall {np.mean([t.metrics.recall for t in head]):.3f}")
    print(f"drift tail: mean cost {np.mean([t.metrics.cost for t in tail]) / 1e3:.0f}K"
          f"  recall {np.mean([t.metrics.recall for t in tail]):.3f}  "
          f"(re-tuned plans, plan cache generation {rt.generation})")


if __name__ == "__main__":
    main()
