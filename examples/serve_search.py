"""End-to-end serving driver: tune → build → serve batched multi-vector
queries through the fused (Pallas-path) scan kernels, with latency stats.

    PYTHONPATH=src python examples/serve_search.py
"""
import time

import numpy as np

from repro.core.types import Constraints
from repro.core.tuner import Mint, ground_truth_cache
from repro.data.vectors import make_database, make_queries, make_workload
from repro.search.engine import execute_plan_fused


def main():
    db = make_database(3000, [("text", 128), ("image", 128), ("audio", 96)],
                       seed=1)
    workload = make_workload(db, "naive", k=20, seed=1)
    mint = Mint(db, index_kind="ivf", seed=1)  # the TPU-native index kind
    result = mint.tune(workload, Constraints(theta_recall=0.85, theta_storage=3))
    gt = ground_truth_cache(db, workload)

    print("serving batched requests (fused distance+topk kernels):")
    for q, _ in workload:
        t0 = time.time()
        ids, cost = execute_plan_fused(db, q, result.plans[q.qid])
        dt = (time.time() - t0) * 1e3
        rec = len(set(map(int, ids)) & set(map(int, gt[q.qid]))) / q.k
        print(f"  {q.name}: top-{q.k} in {dt:6.1f} ms  "
              f"recall={rec:.2f}  cost={cost/1e6:.2f}M dim-dists")

    # replay a burst of 32 queries on the hottest plan
    q = workload.queries[-1]
    burst = make_queries(db, [q.vid] * 6, k=q.k, seed=7)
    t0 = time.time()
    for bq in burst:
        execute_plan_fused(db, bq, result.plans[q.qid])
    dt = time.time() - t0
    n = len(burst)
    print(f"\nburst: {n} queries on {q.name} -> "
          f"{dt/n*1e3:.1f} ms/query (interpret-mode kernels on CPU)")


if __name__ == "__main__":
    main()
