"""End-to-end serving driver: tune → build → compile the request batch into
plan groups → serve through the batched (Pallas-path) engine.

The batch of (query, plan) pairs is compiled so each (plan-group, index)
pair costs ONE fused-kernel dispatch instead of one per query — see
DESIGN.md §Serving.

    PYTHONPATH=src python examples/serve_search.py
"""
import time

import numpy as np

from repro.core.types import Constraints
from repro.core.tuner import Mint, ground_truth_cache
from repro.data.vectors import make_database, make_queries, make_workload
from repro.index.registry import IndexStore
from repro.serve.compiler import dispatch_plan, compile_batch
from repro.serve.engine import BatchEngine


def main():
    db = make_database(3000, [("text", 128), ("image", 128), ("audio", 96)],
                       seed=1)
    workload = make_workload(db, "naive", k=20, seed=1)
    mint = Mint(db, index_kind="ivf", seed=1)  # the TPU-native index kind
    result = mint.tune(workload, Constraints(theta_recall=0.85, theta_storage=3))
    gt = ground_truth_cache(db, workload)

    store = IndexStore(db, seed=1)
    engine = BatchEngine(db, store=store)

    print("serving the workload as ONE compiled batch "
          "(fused distance+topk kernels):")
    pairs = [(q, result.plans[q.qid]) for q, _ in workload]
    t0 = time.time()
    metrics = engine.execute_batch(pairs, gt_cache=gt)
    dt = (time.time() - t0) * 1e3
    for (q, _), m in zip(workload, metrics):
        print(f"  {q.name}: top-{q.k}  recall={m.recall:.2f}  "
              f"cost={m.cost/1e6:.2f}M dim-dists")
    stats = dispatch_plan(compile_batch(pairs))
    print(f"batch: {dt:.1f} ms total — {stats['queries']} queries compiled "
          f"into {stats['groups']} plan groups, "
          f"{stats['batched_scan_dispatches']} scan dispatches "
          f"(vs {stats['per_query_scan_dispatches']} per-query); "
          f"counters={engine.counters.as_dict()}")

    # replay a burst of identical-signature queries on the hottest plan:
    # the whole burst compiles into ONE plan group
    q = workload.queries[-1]
    burst = make_queries(db, [q.vid] * 16, k=q.k, seed=7)
    burst_pairs = [(bq, result.plans[q.qid]) for bq in burst]
    engine.counters.reset()
    t0 = time.time()
    engine.search_batch(burst_pairs)
    dt = time.time() - t0
    n = len(burst)
    print(f"\nburst: {n} queries on {q.name} -> {dt/n*1e3:.1f} ms/query, "
          f"{engine.counters.scan} scan + {engine.counters.rerank} rerank "
          f"dispatches for the whole burst "
          f"(interpret-mode kernels on CPU)")


if __name__ == "__main__":
    main()
