"""Streaming ingest walkthrough: churn → compaction → data-drift retune.

Serves a tuned table while an insert/delete stream mutates it live:
new rows are visible at the next flush (brute-force delta scan merged
with the indexed base), deleted rows never surface (tombstone mask inside
the fused scan), the compactor folds the delta back into the base when it
grows past policy, and when the ingested data DRIFTS away from what the
configuration was tuned for, the data-drift detector fires a compact +
estimator retrain + retune — watch the generation climb and recall hold.

    PYTHONPATH=src python examples/ingest_serve.py
"""
import numpy as np

from repro.core.tuner import Mint
from repro.core.types import Constraints, Workload
from repro.data.vectors import make_database, make_queries
from repro.ingest import CompactionPolicy, IngestConfig, IngestRuntime
from repro.online import RuntimeConfig, churn_trace
from repro.online.trace import TimedMutation


def main():
    cols = [("image", 64), ("title", 48), ("content", 64)]
    db = make_database(4000, cols, seed=2)
    drift_db = make_database(4000, cols, seed=77, spread=2.5, correlation=0.1)
    qs = make_queries(db, [(0,), (0, 1), (1, 2)], k=10, seed=0)
    wl = Workload(queries=qs, probs=np.ones(3))
    cons = Constraints(theta_recall=0.85, theta_storage=3)

    mint = Mint(db, index_kind="ivf", seed=0)
    rt = IngestRuntime(
        db, mint, wl, cons,
        config=RuntimeConfig(max_batch=8, max_delay_ms=5.0, window=64,
                             min_window=32, drift_threshold=2.0,
                             cooldown_s=1e9, measure=True),
        ingest=IngestConfig(
            policy=CompactionPolicy(max_delta_fraction=0.1,
                                    max_dead_fraction=0.15),
            min_mutated_rows=600, churn_threshold=0.25,
            data_cooldown_s=0.0))
    print(f"tuned: {sorted(s.name for s in rt.result.configuration)}")

    trace = churn_trace(db, wl, n=300, qps=500.0, mutation_rate=0.4,
                        batch=16, mix=(0.7, 0.3, 0.0),
                        insert_source=drift_db, query_drift=0.6, seed=1)
    n_mut = sum(isinstance(e, TimedMutation) for e in trace)
    print(f"replaying {len(trace) - n_mut} queries + {n_mut} mutation batches")
    tickets = rt.run_mixed_trace(trace)

    recalls = [t.metrics.recall for t in tickets]
    print(f"\nserved {len(tickets)} queries under churn; "
          f"mean recall {np.mean(recalls):.3f} "
          f"(tail {np.mean(recalls[-30:]):.3f})")
    print(f"table: {rt.table.stats()}")
    for ev in rt.compaction_events:
        print(f"  compaction [{ev.reason}]: {ev.rows_before} -> "
              f"{ev.rows_after} rows, gen {ev.generation}, "
              f"{ev.build_seconds * 1e3:.0f} ms build")
    for ev in rt.data_retune_events:
        print(f"  data retune [{ev.reason}]: churn {ev.churn_fraction:.2f}, "
              f"config {ev.config_before} -> {ev.config_after}, "
              f"gen {ev.generation}, {ev.tune_seconds:.1f}s")
    print(f"final generation: {rt.generation}; "
          f"serving {sorted(s.name for s in rt.result.configuration)}")


if __name__ == "__main__":
    main()
