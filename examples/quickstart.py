"""Quickstart: tune a multi-vector database with MINT and execute the plans.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.types import Constraints, config_name
from repro.core.tuner import Mint, execute_workload, ground_truth_cache
from repro.data.vectors import make_database, make_workload
from repro.index.registry import IndexStore


def main():
    # a 4-column multi-modal database (e.g. image/title/description/content)
    db = make_database(12000, [("image", 128), ("title", 96),
                               ("description", 160), ("content", 192)], seed=0)
    workload = make_workload(db, "news", n_queries=6, k=50, seed=0)
    print("workload:", [q.name for q in workload.queries])

    mint = Mint(db, index_kind="hnsw", seed=0)
    constraints = Constraints(theta_recall=0.9, theta_storage=4)
    result = mint.tune(workload, constraints)
    print("\nrecommended configuration:", config_name(result.configuration))
    for qid in sorted(result.plans):
        print("  ", result.plans[qid].describe())

    # execute on real indexes and compare with the one-index-per-column baseline
    store = IndexStore(db, seed=0)
    gt = ground_truth_cache(db, workload)
    mint_m = execute_workload(db, store, workload, result, gt)
    pc = mint.per_column(workload, constraints)
    pc_m = execute_workload(db, store, workload, pc, gt)
    print(f"\nMINT      cost={mint_m.weighted_cost/1e6:.2f}M  "
          f"recall={mint_m.mean_recall:.3f}  storage={mint_m.storage:.0f}")
    print(f"PerColumn cost={pc_m.weighted_cost/1e6:.2f}M  "
          f"recall={pc_m.mean_recall:.3f}  storage={pc_m.storage:.0f}")
    print(f"speedup:  {pc_m.weighted_cost/max(mint_m.weighted_cost,1):.2f}x")


if __name__ == "__main__":
    main()
